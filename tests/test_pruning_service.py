"""Service-routed SparseGPT/ALPS: bit-identity, re-entrancy, caching.

The PR 4 contract: every ``PruneMethod`` — including the sequential,
gram-based ones — dispatches its transposable block solves through the
batched ``MaskService`` (``solve_plan`` / ``solve_via``), and the routed
masks are bit-identical to the historical inline jitted path at
``SolverConfig.tol = 0``.
"""
import logging

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.backends import register_backend, unregister_backend
from repro.core.solver import SolverConfig, solve_mask
from repro.patterns import PatternSpec
from repro.pruning.alps import AlpsConfig, alps_prune, alps_solve_plan
from repro.pruning.calib import gram_matrix
from repro.pruning.methods import (
    method_solve_plan,
    get_method,
    register_method,
    unregister_method,
)
from repro.pruning.plan import drive_solve_plans
from repro.pruning.sparsegpt import sparsegpt_prune, sparsegpt_solve_plan
from repro.service import BucketPolicy, MaskService
from repro.service.scheduler import StreamStats, solve_stream

FAST = SolverConfig(iters=50)
TINY = BucketPolicy(base=8, growth=2, max_bucket=32)


def make_layer(seed=0, t=256, din=64, dout=96):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(t, din)).astype(np.float32)
    w = rng.normal(size=(din, dout)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(w)


# ---------------------------------------------------------------------------
# Bit-identity: service / callback routes vs the historical inline path.
# ---------------------------------------------------------------------------


class TestBitIdentity:
    @pytest.mark.parametrize("spec", [PatternSpec(4, 8), PatternSpec(2, 4)])
    def test_sparsegpt_routes_identical(self, spec):
        x, w = make_layer(seed=1)
        h = gram_matrix(x)
        wi, mi = sparsegpt_prune(w, h, spec, config=FAST, solve_via="inline")
        svc = MaskService(FAST, policy=TINY)
        ws, ms = sparsegpt_prune(w, h, spec, config=FAST,
                                 solve_via="service", service=svc)
        np.testing.assert_array_equal(np.array(mi), np.array(ms))
        np.testing.assert_array_equal(np.array(wi), np.array(ws))
        # every group's block solve went through the service
        assert svc.stats.submitted == w.shape[0] // spec.m
        assert svc.stats.blocks_solved == (
            (w.shape[0] // spec.m) * (w.shape[1] // spec.m)
        )
        wc, mc = sparsegpt_prune(w, h, spec, config=FAST,
                                 solve_via="callback",
                                 service=MaskService(FAST, policy=TINY))
        np.testing.assert_array_equal(np.array(mi), np.array(mc))
        np.testing.assert_array_equal(np.array(wi), np.array(wc))

    def test_alps_routes_identical(self):
        x, w = make_layer(seed=2, din=64, dout=64)
        h = gram_matrix(x)
        spec = PatternSpec(4, 8)
        cfg = AlpsConfig(iters=20, solver=FAST)
        wi, mi = alps_prune(w, h, spec, config=cfg, solve_via="inline")
        svc = MaskService(FAST, policy=TINY)
        ws, ms = alps_prune(w, h, spec, config=cfg,
                            solve_via="service", service=svc)
        np.testing.assert_array_equal(np.array(mi), np.array(ms))
        np.testing.assert_array_equal(np.array(wi), np.array(ws))
        # init solve + one per ADMM iteration, all through the service
        assert svc.stats.submitted == cfg.iters + 1
        wc, mc = alps_prune(w, h, spec, config=cfg, solve_via="callback",
                            service=MaskService(FAST, policy=TINY))
        np.testing.assert_array_equal(np.array(mi), np.array(mc))
        np.testing.assert_array_equal(np.array(wi), np.array(wc))

    def test_non_transposable_skips_service(self):
        x, w = make_layer(seed=3)
        h = gram_matrix(x)
        spec = PatternSpec(4, 8, transposable=False)
        svc = MaskService(FAST, policy=TINY)
        _, ms = sparsegpt_prune(w, h, spec, config=FAST,
                                solve_via="service", service=svc)
        _, mi = sparsegpt_prune(w, h, spec, config=FAST, solve_via="inline")
        np.testing.assert_array_equal(np.array(mi), np.array(ms))
        assert svc.stats.submitted == 0  # standard N:M never hits the service

    def test_unknown_solve_via_rejected(self):
        x, w = make_layer(seed=4)
        h = gram_matrix(x)
        with pytest.raises(ValueError, match="solve_via"):
            sparsegpt_prune(w, h, PatternSpec(4, 8), solve_via="nope")
        with pytest.raises(ValueError, match="solve_via"):
            alps_prune(w, h, PatternSpec(4, 8), solve_via="nope")


# ---------------------------------------------------------------------------
# The solve_plan protocol + lockstep driver.
# ---------------------------------------------------------------------------


class _StubHandle:
    def __init__(self, mask):
        self._mask = mask

    def result(self):
        return self._mask


class _StubService:
    """Counts sweeps; returns all-ones masks without solving anything."""

    def __init__(self):
        self.flush_sizes = []
        self._batch = 0

    def submit(self, name, w, spec, *, journal=True):
        assert not journal  # sweep requests must not hit the journal
        self._batch += 1
        return _StubHandle(np.ones(np.asarray(w).shape, bool))

    def flush(self):
        self.flush_sizes.append(self._batch)
        self._batch = 0


class TestPlanDriver:
    def test_lockstep_batches_per_sweep(self):
        def plan(n_steps, tag):
            got = []
            for i in range(n_steps):
                mask = yield np.full((4, 4), i + 1, np.float32)
                got.append(mask)
            return tag, got

        svc = _StubService()
        out = drive_solve_plans(
            {"a": plan(2, "A"), "b": plan(4, "B")}, svc, PatternSpec(2, 4)
        )
        # sweeps: {a,b}, {a,b}, {b}, {b} — one flush each, no trailing flush
        assert svc.flush_sizes == [2, 2, 1, 1]
        tag_a, masks_a = out["a"]
        tag_b, masks_b = out["b"]
        assert (tag_a, tag_b) == ("A", "B")
        assert len(masks_a) == 2 and len(masks_b) == 4
        assert all(m.dtype == bool for m in masks_a + masks_b)

    def test_plan_with_no_requests(self):
        def plan():
            return "done", []
            yield  # pragma: no cover - makes this a generator

        out = drive_solve_plans({"p": plan()}, _StubService(), PatternSpec(2, 4))
        assert out["p"] == ("done", [])

    def test_sweep_requests_skip_journal_but_cache(self, tmp_path):
        """Per-sweep solve requests must not fsync a journal record each
        (thousands per layer at scale) — but they DO populate the content
        cache, which is what a resumed run replays from."""
        x, w = make_layer(seed=11, din=16, dout=16)
        h = gram_matrix(x)
        spec = PatternSpec(2, 4)
        svc = MaskService(FAST, policy=TINY, directory=str(tmp_path))
        _, mask = sparsegpt_prune(w, h, spec, config=FAST,
                                  solve_via="service", service=svc)
        assert svc.stats.blocks_solved > 0
        assert svc.journal.completed() == {}  # no per-sweep records

        # A fresh service over the same directory resumes from the cache.
        svc2 = MaskService(FAST, policy=TINY, directory=str(tmp_path))
        _, mask2 = sparsegpt_prune(w, h, spec, config=FAST,
                                   solve_via="service", service=svc2)
        assert svc2.stats.blocks_solved == 0  # pure disk-cache hits
        np.testing.assert_array_equal(np.array(mask), np.array(mask2))

    def test_registered_methods_expose_plans(self):
        assert method_solve_plan(get_method("sparsegpt")) is not None
        assert method_solve_plan(get_method("alps")) is not None
        assert method_solve_plan(get_method("wanda")) is None

    def test_plan_generators_match_prune_functions(self):
        x, w = make_layer(seed=5, din=32, dout=32)
        h = gram_matrix(x)
        spec = PatternSpec(2, 4)
        svc = MaskService(FAST, policy=TINY)
        plans = {
            "sgpt": sparsegpt_solve_plan(w, h, spec),
            "alps": alps_solve_plan(w, h, spec, AlpsConfig(iters=5, solver=FAST)),
        }
        solved = drive_solve_plans(plans, svc, spec)
        _, m_ref = sparsegpt_prune(w, h, spec, config=FAST, solve_via="inline")
        np.testing.assert_array_equal(np.array(solved["sgpt"][1]), np.array(m_ref))
        _, a_ref = alps_prune(w, h, spec, config=AlpsConfig(iters=5, solver=FAST),
                              solve_via="inline")
        np.testing.assert_array_equal(np.array(solved["alps"][1]), np.array(a_ref))


# ---------------------------------------------------------------------------
# Engine: re-entrant submit during an active flush; batched futures.
# ---------------------------------------------------------------------------


class _ReentrantBackend:
    """Delegates to dense-jit but submits a NEW tensor to the service the
    first time it solves — simulating an io_callback firing mid-flush."""

    name = "reentrant-test"
    traceable = False

    def __init__(self):
        self.service = None
        self.extra = None
        self.inner_handle = None

    def solve(self, w_abs_blocks, pattern, config):
        from repro.core.backends import get_backend

        if self.inner_handle is None and self.service is not None:
            self.inner_handle = self.service.submit(
                "inner", self.extra, pattern
            )
        inner_cfg = SolverConfig(
            iters=config.iters, ls_steps=config.ls_steps,
            tau_scale=config.tau_scale, tol=config.tol,
        )
        return get_backend("dense-jit").solve(w_abs_blocks, pattern, inner_cfg)


class TestReentrantFlush:
    def test_submit_during_flush_resolves_in_same_call(self):
        backend = _ReentrantBackend()
        register_backend(backend, overwrite=True)
        try:
            cfg = SolverConfig(iters=50, backend="reentrant-test")
            svc = MaskService(cfg, policy=TINY)
            rng = np.random.default_rng(6)
            outer = rng.normal(size=(8, 8)).astype(np.float32)
            extra = rng.normal(size=(8, 16)).astype(np.float32)
            backend.service, backend.extra = svc, extra

            h = svc.submit("outer", outer, PatternSpec(4, 8))
            svc.flush()
            # both the outer tensor and the mid-flush submission resolved
            assert h.done and backend.inner_handle is not None
            assert backend.inner_handle.done
            want = np.array(solve_mask(jnp.asarray(extra), PatternSpec(4, 8), FAST))
            np.testing.assert_array_equal(
                np.array(backend.inner_handle.result()), want
            )
        finally:
            unregister_backend("reentrant-test")

    def test_submit_many_and_results(self):
        svc = MaskService(FAST, policy=TINY)
        rng = np.random.default_rng(7)
        tensors = [(f"t{i}", rng.normal(size=(8, 8)).astype(np.float32))
                   for i in range(3)]
        handles = svc.submit_many(tensors, PatternSpec(4, 8))
        assert [h.name for h in handles] == ["t0", "t1", "t2"]
        batches_before = svc.stats.batches
        masks = svc.results(handles)
        assert all(h.done for h in handles)
        assert len(masks) == 3
        for (_, w), mask in zip(tensors, masks):
            np.testing.assert_array_equal(
                np.array(mask),
                np.array(solve_mask(jnp.asarray(w), PatternSpec(4, 8), FAST)),
            )
        # resolving again is free: no extra flush work
        svc.results(handles)
        assert svc.stats.batches == batches_before + 1

    def test_results_rejects_foreign_handles(self):
        svc1 = MaskService(FAST, policy=TINY)
        svc2 = MaskService(FAST, policy=TINY)
        h = svc1.submit("w", np.ones((8, 8), np.float32), PatternSpec(4, 8))
        with pytest.raises(ValueError, match="different MaskService"):
            svc2.results([h])
        svc1.flush()


# ---------------------------------------------------------------------------
# Scheduler: sub-base rungs for many-small-blocks streams; log-once fix.
# ---------------------------------------------------------------------------


class TestSmallStreamBucketing:
    def test_sub_rungs_ladder(self):
        p = BucketPolicy(base=64, growth=4, max_bucket=256, min_bucket=8)
        assert p.sub_rungs() == (32, 16, 8)
        assert BucketPolicy(base=64).sub_rungs() == ()  # historic default

    def test_plan_small_stream_avoids_base_roundup(self):
        p = BucketPolicy(base=64, growth=4, max_bucket=256, min_bucket=8,
                         tail_decompose=True)
        assert p.plan(12) == [8, 8]          # padding 4, not 52
        assert p.plan(3) == [8]
        assert p.plan(100) == [64, 32, 8]    # padding 4
        assert p.plan(64) == [64]
        # covering-rung mode picks the smallest sub rung that covers
        q = BucketPolicy(base=64, growth=4, max_bucket=256, min_bucket=8)
        assert q.plan(12) == [16]

    def test_min_bucket_zero_is_bit_compatible(self):
        # the exact cases of test_service.test_bucket_plan_ladder
        p = BucketPolicy(base=8, growth=4, max_bucket=128)
        assert p.plan(128 * 3 + 40) == [128, 128, 128, 128]
        assert p.plan(7) == [8]
        assert p.plan(9) == [32]

    def test_for_device_sets_min_bucket(self):
        from repro.kernels.vmem import VPU_ALIGN

        p = BucketPolicy.for_device(8)
        assert p.min_bucket == min(VPU_ALIGN, p.base)
        assert p.tail_decompose

    def test_small_streams_solve_bit_exact(self):
        rng = np.random.default_rng(8)
        w = rng.normal(size=(8, 24)).astype(np.float32)  # 3 blocks << base
        policy = BucketPolicy(base=64, growth=4, max_bucket=256, min_bucket=8,
                              tail_decompose=True)
        svc = MaskService(FAST, policy=policy)
        mask = svc.solve(w, PatternSpec(4, 8))
        np.testing.assert_array_equal(
            np.array(mask),
            np.array(solve_mask(jnp.asarray(w), PatternSpec(4, 8), FAST)),
        )
        assert svc.stats.stream.blocks_padded == 5  # 3 real in one 8-bucket


class TestPaddingWasteLogging:
    def test_solve_stream_is_quiet_at_info(self, caplog):
        blocks = np.abs(np.random.default_rng(9).normal(size=(4, 8, 8))
                        ).astype(np.float32)
        stats = StreamStats()
        with caplog.at_level(logging.INFO, logger="repro.service.scheduler"):
            for _ in range(3):  # sequential solvers call this once per sweep
                solve_stream([blocks], PatternSpec(4, 8), FAST, TINY, stats)
        assert not [r for r in caplog.records
                    if r.name == "repro.service.scheduler"
                    and r.levelno >= logging.INFO]

    def test_stream_stats_summary_aggregates(self):
        stats = StreamStats()
        stats.note_batch(8, 6, 2)
        stats.note_batch(8, 8, 0)
        stats.note_batch(16, 10, 6)
        line = stats.summary()
        assert "blocks=24" in line and "batches=3" in line
        assert "padded=8" in line and "waste_per_bucket=" in line
        assert "8:0.125" in line and "16:0.375" in line


# ---------------------------------------------------------------------------
# prune_transformer: service-routed SparseGPT/ALPS vs pre-PR inline masks,
# and cache hits across a two-model prune.
# ---------------------------------------------------------------------------


def _tiny_lm(seed=0):
    from repro.models.config import ModelConfig
    from repro.models import lm

    cfg = ModelConfig("psvc-test", "dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                      remat="none", dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    tokens = jnp.asarray(
        np.random.default_rng(seed).integers(0, 64, size=(2, 16))
    )
    return cfg, params, tokens


def _register_inline_twin(method_name):
    """The pre-PR behavior: same method, solves inlined in its jitted loop."""
    if method_name == "sparsegpt":
        def fn(w, gram, pattern, ctx):
            h = gram if gram is not None else ctx.gram()
            return sparsegpt_prune(w, h, pattern, config=ctx.solver,
                                   solve_via="inline")
        return register_method("inline-twin", fn, needs_gram=True,
                               overwrite=True)
    def fn(w, gram, pattern, ctx):
        h = gram if gram is not None else ctx.gram()
        cfg = ctx.alps if ctx.alps is not None else AlpsConfig(solver=ctx.solver)
        return alps_prune(w, h, pattern, config=cfg, solve_via="inline")
    return register_method("inline-twin", fn, needs_gram=True, overwrite=True)


@pytest.mark.parametrize("method,alps_iters", [("sparsegpt", None), ("alps", 4)])
def test_prune_transformer_service_routed_matches_inline(method, alps_iters):
    from repro.pruning.runner import prune_transformer

    cfg, params, tokens = _tiny_lm()
    solver = SolverConfig(iters=40)
    alps_cfg = AlpsConfig(iters=alps_iters, solver=solver) if alps_iters else None
    svc = MaskService(solver, policy=TINY)
    pruned, masks = prune_transformer(
        params, cfg, tokens=tokens, method=method, pattern=PatternSpec(2, 4),
        solver=solver, alps_cfg=alps_cfg, service=svc,
    )
    # ALL of the method's transposable block solves went through the service
    assert svc.stats.submitted > 0 and svc.stats.blocks_solved > 0

    _register_inline_twin(method)
    try:
        pruned_ref, masks_ref = prune_transformer(
            params, cfg, tokens=tokens, method="inline-twin",
            pattern=PatternSpec(2, 4), solver=solver, alps_cfg=alps_cfg,
        )
    finally:
        unregister_method("inline-twin")
    for a, b in zip(jax.tree.leaves(masks), jax.tree.leaves(masks_ref)):
        np.testing.assert_array_equal(np.array(a), np.array(b))
    for a, b in zip(jax.tree.leaves(pruned), jax.tree.leaves(pruned_ref)):
        np.testing.assert_array_equal(np.array(a), np.array(b))


def test_two_model_prune_hits_cache():
    """Pruning a second identical model re-solves NOTHING: every sequential
    solve request is content-addressed, so model #2 is pure cache hits."""
    from repro.pruning.runner import prune_transformer

    cfg, params, tokens = _tiny_lm()
    solver = SolverConfig(iters=40)
    svc = MaskService(solver, policy=TINY)
    _, masks1 = prune_transformer(
        params, cfg, tokens=tokens, method="sparsegpt",
        pattern=PatternSpec(2, 4), solver=solver, service=svc,
    )
    solved_first = svc.stats.blocks_solved
    submitted_first = svc.stats.submitted
    hits_first = svc.stats.cache_hits
    assert solved_first > 0 and hits_first == 0

    _, masks2 = prune_transformer(
        params, cfg, tokens=tokens, method="sparsegpt",
        pattern=PatternSpec(2, 4), solver=solver, service=svc,
    )
    assert svc.stats.blocks_solved == solved_first      # zero new solves
    assert svc.stats.cache_hits - hits_first == svc.stats.submitted - submitted_first
    for a, b in zip(jax.tree.leaves(masks1), jax.tree.leaves(masks2)):
        np.testing.assert_array_equal(np.array(a), np.array(b))
