"""Pruning frameworks: quality orderings and paper-claimed trends."""
import numpy as np
import jax.numpy as jnp

from repro.core.solver import SolverConfig, is_transposable_nm
from repro.patterns import PatternSpec
from repro.pruning import (
    alps_prune,
    gram_matrix,
    magnitude_prune,
    reconstruction_error,
    sparsegpt_prune,
    wanda_prune,
)
from repro.pruning.alps import AlpsConfig


def make_layer(seed=0, t=384, din=64, dout=96):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(t, 12)) @ rng.normal(size=(12, din))
         + 0.3 * rng.normal(size=(t, din))).astype(np.float32)
    w = rng.normal(size=(din, dout)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(w)


FAST = SolverConfig(iters=80)


class TestOrdering:
    def test_alps_beats_sparsegpt_beats_wanda(self):
        x, w = make_layer()
        h = gram_matrix(x)
        n, m = 4, 8
        errs = {}
        for name, (wp, mask) in {
            "wanda": wanda_prune(w, x, PatternSpec(n, m), config=FAST),
            "sparsegpt": sparsegpt_prune(w, h, PatternSpec(n, m), config=FAST),
            "alps": alps_prune(w, h, PatternSpec(n, m), config=AlpsConfig(iters=50, solver=FAST)),
        }.items():
            assert is_transposable_nm(np.array(mask), n, m), name
            errs[name] = float(reconstruction_error(x, w, wp))
        assert errs["alps"] <= errs["sparsegpt"] <= errs["wanda"], errs

    def test_transposable_weaker_than_standard(self):
        """Paper Tab. 4: transposable error >= standard N:M error."""
        x, w = make_layer(seed=1)
        h = gram_matrix(x)
        n, m = 4, 8
        wt, _ = alps_prune(w, h, PatternSpec(n, m, True),
                           config=AlpsConfig(iters=50, solver=FAST))
        ws, _ = alps_prune(w, h, PatternSpec(n, m, False),
                           config=AlpsConfig(iters=50, solver=FAST))
        et = float(reconstruction_error(x, w, wt))
        es = float(reconstruction_error(x, w, ws))
        assert es <= et * 1.05  # standard N:M is the weaker constraint

    def test_gap_shrinks_with_larger_m(self):
        """Paper Sec. 5.2.1: transposable-vs-standard gap shrinks as M grows."""
        x, w = make_layer(seed=2, din=128, dout=64)
        h = gram_matrix(x)
        gaps = {}
        for m in (4, 16):
            n = m // 2
            wt, _ = alps_prune(w, h, PatternSpec(n, m, True),
                               config=AlpsConfig(iters=50, solver=FAST))
            ws, _ = alps_prune(w, h, PatternSpec(n, m, False),
                               config=AlpsConfig(iters=50, solver=FAST))
            et = float(reconstruction_error(x, w, wt))
            es = float(reconstruction_error(x, w, ws))
            gaps[m] = et - es
        assert gaps[16] <= gaps[4] + 1e-3, gaps


class TestMechanics:
    def test_magnitude_prune_mask(self):
        _, w = make_layer(seed=3)
        wp, mask = magnitude_prune(w, PatternSpec(2, 8), config=FAST)
        assert is_transposable_nm(np.array(mask), 2, 8)
        assert float(jnp.sum(jnp.abs(wp))) > 0
        np.testing.assert_array_equal(np.array(wp == 0), ~np.array(mask))

    def test_sparsegpt_updates_reduce_error_vs_pure_mask(self):
        x, w = make_layer(seed=4)
        h = gram_matrix(x)
        wp, mask = sparsegpt_prune(w, h, PatternSpec(4, 8), config=FAST)
        masked_only = jnp.where(mask, w, 0)
        e_upd = float(reconstruction_error(x, w, wp))
        e_raw = float(reconstruction_error(x, w, masked_only))
        assert e_upd < e_raw  # OBS compensation must help

    def test_alps_safeguard_feasible_every_m(self):
        x, w = make_layer(seed=5, din=64, dout=64)
        h = gram_matrix(x)
        for n, m in [(2, 4), (2, 8), (8, 16)]:
            _, mask = alps_prune(w, h, PatternSpec(n, m),
                                 config=AlpsConfig(iters=25, solver=FAST))
            assert is_transposable_nm(np.array(mask), n, m), (n, m)

    def test_wanda_importance_differs_from_magnitude(self):
        x, w = make_layer(seed=6)
        _, mw = wanda_prune(w, x, PatternSpec(4, 8), config=FAST)
        _, mm = magnitude_prune(w, PatternSpec(4, 8), config=FAST)
        assert (np.array(mw) != np.array(mm)).any()
