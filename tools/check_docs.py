"""Docs gate: markdown link check + doctest-style execution of examples.

Two checks, run by the CI ``docs`` job and by ``tests/test_docs.py``:

1. **Links** — every relative markdown link in ``docs/*.md`` and
   ``README.md`` must point at an existing file, and every in-document
   anchor (``#...``, own-file or cross-file) must match a heading slug of
   the target document (GitHub slugification).  External ``http(s)``/
   ``mailto`` links are not fetched (CI must pass offline).
2. **Examples** — every fenced ```` ```python ```` block in ``docs/*.md``
   is executed, top to bottom, with one shared namespace per file (so
   later blocks may build on earlier ones, like a doctest session).  Use a
   different fence language (``text``, ``pycon``) for non-executable
   listings.

Usage::

    PYTHONPATH=src python tools/check_docs.py [--no-exec]

Exits non-zero with a per-finding report on any failure.
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

# Single-core hosts need a second XLA host device or doc examples using
# solve_via="callback" deadlock — shared helper, also used by
# tests/conftest.py.  Must run before the examples import jax.
from repro.hostenv import single_core_xla_workaround  # noqa: E402

single_core_xla_workaround()

# [text](target) — excluding images' leading "!" is unnecessary: image
# targets must exist just like link targets.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*$", re.MULTILINE)
_FENCE_RE = re.compile(r"^```(\w*)\s*$")


def doc_files() -> list[pathlib.Path]:
    return sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]


def _rel(path: pathlib.Path) -> str:
    try:
        return str(path.relative_to(REPO))
    except ValueError:  # e.g. a test fixture under /tmp
        return str(path)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug for a heading line (good enough for our docs):
    drop code ticks, lowercase, strip non [alnum spaces hyphens underscores],
    spaces -> hyphens."""
    s = heading.replace("`", "").lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def heading_slugs(path: pathlib.Path) -> set[str]:
    text = path.read_text()
    slugs: set[str] = set()
    # Headings inside fenced blocks are not anchors; strip fences first.
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    for _level, title in _HEADING_RE.findall(text):
        slugs.add(github_slug(title))
    return slugs


def check_links(paths=None) -> list[str]:
    """Returns a list of human-readable problems (empty = all links OK)."""
    problems = []
    for path in paths if paths is not None else doc_files():
        text = path.read_text()
        text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)  # skip code
        for target in _LINK_RE.findall(text):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:, …
                continue
            file_part, _, anchor = target.partition("#")
            if file_part:
                dest = (path.parent / file_part).resolve()
                if not dest.exists():
                    problems.append(f"{_rel(path)}: broken link "
                                    f"-> {target} (no such file)")
                    continue
            else:
                dest = path
            if anchor and dest.suffix == ".md":
                if github_slug(anchor) not in heading_slugs(dest):
                    problems.append(
                        f"{_rel(path)}: broken anchor -> "
                        f"{target} (no heading '#{anchor}' in "
                        f"{_rel(dest)})"
                    )
    return problems


def python_blocks(path: pathlib.Path) -> list[tuple[int, str]]:
    """(start line, source) for every ```python fenced block in ``path``."""
    blocks = []
    lines = path.read_text().splitlines()
    in_block, lang, start, buf = False, "", 0, []
    for i, line in enumerate(lines, 1):
        fence = _FENCE_RE.match(line)
        if fence and not in_block:
            in_block, lang, start, buf = True, fence.group(1), i + 1, []
        elif line.strip() == "```" and in_block:
            if lang == "python":
                blocks.append((start, "\n".join(buf)))
            in_block = False
        elif in_block:
            buf.append(line)
    return blocks


def run_python_blocks(path: pathlib.Path) -> list[str]:
    """Execute a file's python blocks in one shared namespace; returns
    problems (empty = every example ran)."""
    namespace: dict = {"__name__": f"docs:{path.name}"}
    problems = []
    for start, source in python_blocks(path):
        try:
            code = compile(source, f"{path}:{start}", "exec")
            exec(code, namespace)  # noqa: S102 - that's the point
        except Exception as exc:  # pragma: no cover - failure reporting
            problems.append(
                f"{_rel(path)}:{start}: example failed: "
                f"{type(exc).__name__}: {exc}"
            )
            break  # later blocks in this file depend on this one
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--no-exec", action="store_true",
                    help="only check links, skip running the examples")
    args = ap.parse_args(argv)

    problems = check_links()
    if not args.no_exec:
        for path in sorted((REPO / "docs").glob("*.md")):
            problems += run_python_blocks(path)
    for p in problems:
        print(f"FAIL {p}")
    if not problems:
        n_docs = len(list((REPO / 'docs').glob('*.md')))
        print(f"docs OK: {n_docs} docs + README links good, examples ran")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
