"""Perf-regression gate: declarative checks over every ``BENCH_*.json``.

Evaluates the check suite in :mod:`repro.perf.checks` — reframe-style
declarative checks with extraction expressions, sanity conditions and trend
references — against a *current* directory of benchmark documents, diffing
trends against a *baseline* directory (by default both are the repo root,
i.e. the committed files validate against themselves: sanity gates run,
trend deltas are zero).

CI usage (the ``perfcheck`` job)::

    # 1. Validate the committed baselines: zero sanity failures required.
    PYTHONPATH=src python tools/perfcheck.py --require-all --report report.json

    # 2. Diff a fresh smoke run against the committed baselines.  Trend
    #    comparisons only fire for comparable runs (same model/shape/device
    #    fingerprint); smoke-vs-full mismatches skip the trend and keep the
    #    sanity gates.
    PYTHONPATH=src python tools/perfcheck.py --current perf_scratch --baseline .

Exit status is non-zero — naming the failing check — on any sanity failure
or gated trend regression.  ``--report`` writes the full trend report
(per-check values, deltas, verdicts) for artifact upload.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.perf.checks import CHECKS, evaluate_all  # noqa: E402


def format_result(res) -> str:
    flag = {
        "ok": "OK  ",
        "skipped": "SKIP",
        "missing": "MISS",
        "sanity_failed": "FAIL",
        "regressed": "FAIL",
    }[res.status]
    lines = [f"{flag} {res.check} [{res.bench}] {res.status}"]
    for s in res.sanity_failures:
        lines.append(f"       sanity: {s}")
    for row in res.trend_rows:
        def _fmt(v):
            if isinstance(v, list):
                return "[" + ", ".join(f"{x:.4g}" for x in v) + "]"
            return f"{v:.6g}"
        lines.append(
            f"       trend {row['var']}: {_fmt(row['baseline'])} -> "
            f"{_fmt(row['current'])} (worst {row['delta_frac']:+.1%}, "
            f"band ±{row['tolerance']:.0%}, {row['direction']}-is-better) "
            f"{row['verdict']}{' [warn-only]' if row['mode'] == 'warn' else ''}"
        )
    for note in res.notes:
        lines.append(f"       note: {note}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--current", default=str(REPO),
                    help="directory holding the BENCH_*.json under test "
                         "(default: repo root — the committed files)")
    ap.add_argument("--baseline", default=str(REPO),
                    help="directory holding the baseline BENCH_*.json trends "
                         "are diffed against (default: repo root)")
    ap.add_argument("--report", default=None,
                    help="write the full JSON trend report here")
    ap.add_argument("--only", default=None, metavar="CHECK",
                    help="run a single check by name")
    ap.add_argument("--require-all", action="store_true",
                    help="a required check whose bench file is missing from "
                         "--current fails instead of skipping")
    ap.add_argument("--list", action="store_true", help="list checks and exit")
    args = ap.parse_args(argv)

    if args.list:
        for check in CHECKS:
            gates = sum(1 for t in check.trends if t.mode == "gate")
            print(f"{check.name:24s} {check.bench:22s} "
                  f"{len(check.sanity)} sanity, {len(check.trends)} trends "
                  f"({gates} gating){'' if check.required else ' [optional]'}")
        return 0

    results = evaluate_all(
        args.current, args.baseline,
        require_all=args.require_all, only=args.only,
    )
    if args.only and not results:
        print(f"FAIL no check named {args.only!r}", file=sys.stderr)
        return 2

    failed = []
    for res in results:
        print(format_result(res))
        if res.gating_failure:
            failed.append(res.check)

    if args.report:
        report = {
            "current": str(args.current),
            "baseline": str(args.baseline),
            "failed": failed,
            "checks": [r.to_json() for r in results],
        }
        path = pathlib.Path(args.report)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(report, indent=1) + "\n")
        print(f"wrote {path}")

    n_ok = sum(1 for r in results if r.status == "ok")
    n_skip = sum(1 for r in results if r.status == "skipped")
    if failed:
        print(f"perfcheck: {len(failed)} check(s) FAILED: "
              f"{', '.join(failed)}", file=sys.stderr)
        return 1
    print(f"perfcheck OK: {n_ok} check(s) passed, {n_skip} skipped")
    return 0


if __name__ == "__main__":
    sys.exit(main())
