"""Paper Fig. 5 (miniature): fine-tuning transposable N:M sparse models.

TSENOR+pruning then sparse fine-tune with exact (masked) gradients, for two
M values — validates that fine-tuning recovers loss and that larger M
recovers more of the dense quality.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.api import PatternSpec, SolverConfig
from repro.data import SyntheticLM
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import AdamW, warmup_cosine
from repro.sparsity.masks import apply_mask, sparsify_pytree
from repro.train import TrainLoop, TrainLoopConfig, build_train_step, make_train_state

CFG = ModelConfig("ft-lm", "dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=128, remat="none",
                  dtype="float32")


def eval_loss(params, data, steps=4):
    return float(np.mean([
        float(lm.loss_fn(params, CFG, {k: jnp.asarray(v) for k, v in
                                       data.batch(60_000 + i).items()}))
        for i in range(steps)
    ]))


def run():
    data = SyntheticLM(vocab_size=CFG.vocab_size, seq_len=32, global_batch=8)
    opt = AdamW(learning_rate=warmup_cosine(5e-3, 10, 150))
    state = make_train_state(CFG, opt, jax.random.PRNGKey(0))
    loop = TrainLoop(build_train_step(CFG, opt), data, None,
                     TrainLoopConfig(total_steps=150, log_every=10**9),
                     log_fn=lambda s: None)
    state, _ = loop.run(state)
    dense = eval_loss(state.params, data)
    emit("finetune_dense", 0.0, f"loss={dense:.4f}")

    for n, m in [(2, 4), (8, 16)]:
        masks = sparsify_pytree(state.params, PatternSpec(n, m),
                                config=SolverConfig(iters=80))
        pruned = apply_mask(state.params, masks)
        before = eval_loss(pruned, data)
        opt_ft = AdamW(learning_rate=1e-3)
        st = make_train_state(CFG, opt_ft, jax.random.PRNGKey(1))
        st = st._replace(params=pruned)
        loop_ft = TrainLoop(build_train_step(CFG, opt_ft, masks=masks, donate=False), data, None,
                            TrainLoopConfig(total_steps=80, log_every=10**9),
                            log_fn=lambda s: None)
        st, _ = loop_ft.run(st)
        after = eval_loss(apply_mask(st.params, masks), data)
        emit(f"finetune_{n}:{m}", 0.0,
             f"pruned={before:.4f};finetuned={after:.4f};dense={dense:.4f}")


if __name__ == "__main__":
    run()
