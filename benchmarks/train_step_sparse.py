"""Train-step throughput + weight-traffic model: dense vs masked vs compressed.

Times one optimizer step of the same model under the three execution modes

* ``dense``        — no sparsity (reference);
* ``masked-dense`` — ``mask_mode="fwd"``: dense weights multiplied by bool
  masks inside the forward (the paper-faithful sparse fine-tune);
* ``compressed``   — ``mask_mode="compressed"``: SparseParams, every pruned
  projection streamed from its ``(values, int8 indices)`` buffer through the
  nm_spmm kernel, forward AND backward (transposable masks: one buffer for
  ``W·x`` and ``Wᵀ·g``);

and writes a machine-readable ``BENCH_train.json`` with:

* ``tokens_per_sec`` — median wall-clock step throughput per mode;
* ``weight_stream_bytes`` — analytic HBM weight traffic of one step's
  matmuls (forward read + backward read) per mode, from the real buffer
  sizes: ``2 × Σ dense_bytes`` for the dense modes (plus mask reads for
  masked-dense) and ``2 × Σ (values+indices)`` for compressed;
* ``bytes_ratio`` — compressed/dense of the above, which must match the
  :func:`repro.sparsity.compressed.compressed_bytes` analytic ratio within
  10% (asserted in ``--smoke``: the CI regression gate);
* ``actgrad_stream_bytes`` / ``total_stream_bytes`` (``accounting:
  train-v2``) — the backward's activation-gradient traffic (each
  projection's f32 cotangent read by both backward matmuls), identical
  across modes, and the weight+actgrad total whose compressed/dense ratio
  (``bytes_ratio_total``) is the end-to-end figure — see
  ``benchmarks/backward_sparse.py`` for the ``grad_sparsity`` path that
  shrinks the actgrad term too;
* a bit-identity gate (``--smoke`` only): the masked-dense and compressed
  first-step losses must agree exactly.  The smoke model's projections fit
  a single nm_spmm K-tile, where the kernel's accumulation order matches
  the dense dot; the full config spans multiple K-tiles, where per-tile
  accumulation differs from dense in ULPs, so the full run reports
  ``loss_abs_delta`` instead of asserting equality.

On this CPU container the Pallas kernel runs in interpret mode, so
``tokens_per_sec`` for ``compressed`` measures dispatch overhead, not TPU
bandwidth — the traffic model is the portable number.

Run:    PYTHONPATH=src:. python benchmarks/train_step_sparse.py
Smoke:  PYTHONPATH=src:. python benchmarks/train_step_sparse.py --smoke
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.api import PatternSpec, SolverConfig
from repro.data import SyntheticLM
from repro.kernels import default_interpret
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import AdamW
from repro.sparsity.compressed import compressed_bytes
from repro.sparsity.masks import apply_mask, sparsify_pytree
from repro.sparsity.params import (
    PROJ_KEYS,
    NMCompressed,
    compress_params,
    projection_prunable,
    sparse_param_bytes,
)
from repro.train import build_train_step, make_train_state
from repro.train.step import StepConfig
from repro.treepath import path_entry_str

SMOKE_CFG = ModelConfig("bench-smoke", "dense", num_layers=2, d_model=64,
                        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                        remat="none", dtype="float32")
FULL_CFG = ModelConfig("bench-30m", "dense", num_layers=6, d_model=384,
                       num_heads=6, num_kv_heads=2, d_ff=1536, vocab_size=8192,
                       remat="none", dtype="float32")


def _time_steps(step_fn, state, batches, reps: int) -> tuple[float, float]:
    """(median seconds/step, first-step loss). Compiles on batch 0 first."""
    state, metrics = step_fn(state, batches[0])
    first_loss = float(np.asarray(metrics["loss"]))
    ts = []
    for r in range(reps):
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batches[(r + 1) % len(batches)])
        jax.block_until_ready(metrics["loss"])
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), first_loss


def _weight_stream_bytes(params, mode: str) -> int:
    """Analytic HBM weight traffic of one step's projection matmuls.

    Each projection is read twice per step (forward X·W, backward dY·Wᵀ);
    masked-dense additionally reads the bool mask in both passes.  Embedding
    and unembedding traffic is identical across modes and excluded.
    """
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, NMCompressed)
    )[0]:
        name = path_entry_str(path[-1]) if path else ""
        if isinstance(leaf, NMCompressed):
            total += 2 * leaf.nbytes()
        elif name in PROJ_KEYS:  # the proj()-dispatched execution surface
            total += 2 * int(leaf.nbytes)
            if mode == "masked-dense":
                total += 2 * int(leaf.size)  # bool mask, 1 byte/elem
    return total


def _actgrad_stream_bytes(params, tokens: int) -> int:
    """Analytic HBM activation-gradient traffic of one step's backward.

    Each projection's f32 cotangent ``dY (tokens, F)`` is read by BOTH
    backward matmuls (dX = dY·Wᵀ and dW = Xᵀ·dY) — 2 × tokens × F × 4 bytes
    per projection regardless of how the weights are stored, so it is
    identical across the three modes.  Omitting it (the pre-``accounting:
    train-v2`` documents) understates dense-mode traffic and so *overstates*
    the compressed/dense total ratio; ``weight_stream_bytes`` is kept as the
    weights-only figure the ``compressed_bytes`` analytic model predicts.
    """
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, NMCompressed)
    )[0]:
        name = path_entry_str(path[-1]) if path else ""
        if isinstance(leaf, NMCompressed):
            shape = leaf.dense_shape
        elif name in PROJ_KEYS:
            shape = leaf.shape
        else:
            continue
        layers = int(np.prod(shape[:-2])) if len(shape) > 2 else 1
        total += layers * 2 * tokens * int(shape[-1]) * 4
    return total


def run(cfg: ModelConfig, spec: PatternSpec, seq: int, batch: int, reps: int,
        solver_iters: int, out_path: str) -> dict:
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq,
                       global_batch=batch)
    batches = [{k: jnp.asarray(v) for k, v in data.batch(i).items()}
               for i in range(max(2, reps))]
    tokens_per_step = seq * batch

    params = jax.block_until_ready(lm.init_params(cfg, jax.random.PRNGKey(0)))
    masks = sparsify_pytree(params, spec,
                            config=SolverConfig(iters=solver_iters),
                            prunable=projection_prunable)
    pruned = apply_mask(params, masks)
    sp = compress_params(pruned, masks, spec)
    opt = AdamW(learning_rate=1e-3, clip_norm=0.0)

    modes = {
        "dense": (params, None, StepConfig()),
        "masked-dense": (pruned, masks, StepConfig(mask_mode="fwd")),
        "compressed": (sp, None, StepConfig(mask_mode="compressed")),
    }
    results, losses = [], {}
    for mode, (p, mk, scfg) in modes.items():
        state = make_train_state(cfg, opt, jax.random.PRNGKey(1), params=p)
        step = build_train_step(cfg, opt, masks=mk, step_cfg=scfg,
                                donate=False)
        sec, loss = _time_steps(step, state, batches, reps)
        losses[mode] = loss
        stream = _weight_stream_bytes(p, mode)
        actgrad = _actgrad_stream_bytes(p, tokens_per_step)
        row = {
            "mode": mode,
            "seconds_per_step": sec,
            "tokens_per_sec": tokens_per_step / sec,
            "weight_stream_bytes": stream,
            "actgrad_stream_bytes": actgrad,
            "total_stream_bytes": stream + actgrad,
            "first_step_loss": loss,
        }
        results.append(row)
        emit(f"train_step_{mode}", sec,
             f"tok/s={row['tokens_per_sec']:.0f} stream={stream}")

    by_mode = {r["mode"]: r for r in results}
    ratio_bench = (by_mode["compressed"]["weight_stream_bytes"]
                   / by_mode["dense"]["weight_stream_bytes"])
    ratio_total = (by_mode["compressed"]["total_stream_bytes"]
                   / by_mode["dense"]["total_stream_bytes"])

    # Analytic model: aggregate compressed_bytes() over the projections.
    bytes_w = jnp.dtype(cfg.param_dtype).itemsize
    dense_b = comp_b = 0
    for leaf in jax.tree.leaves(sp, is_leaf=lambda x: isinstance(x, NMCompressed)):
        if isinstance(leaf, NMCompressed):
            shape = leaf.dense_shape
            layers = int(np.prod(shape[:-2])) if len(shape) > 2 else 1
            acc = compressed_bytes(int(shape[-2]), int(shape[-1]), leaf.n,
                                   leaf.m, bytes_w=bytes_w)
            dense_b += layers * acc["dense"]
            comp_b += layers * acc["compressed"]
    ratio_analytic = comp_b / dense_b
    footprint = sparse_param_bytes(sp)

    doc = {
        "meta": {
            "benchmark": "train_step_sparse",
            "platform": platform.platform(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "device": str(jax.local_devices()[0].device_kind),
            "interpret_mode": default_interpret(),
            "model": cfg.name,
            "pattern": str(spec),
            "seq_len": seq,
            "batch": batch,
            "reps": reps,
            # Bytes-accounting schema: "train-v2" adds activation-gradient
            # traffic (actgrad_stream_bytes / total_stream_bytes / the total
            # ratio).  In compare_keys, so v1 baselines are never trend-
            # diffed against v2 documents.
            "accounting": "train-v2",
        },
        "headline": {
            "bytes_ratio_bench": ratio_bench,
            "bytes_ratio_analytic": ratio_analytic,
            # Weight + activation-gradient traffic: the actgrad term is
            # mode-invariant, so this ratio is closer to 1 than the weights-
            # only ratio — it is the honest end-to-end backward-inclusive
            # number (BENCH_backward.json's grad_sparsity path is what
            # shrinks the actgrad term itself).
            "bytes_ratio_total": ratio_total,
            "param_footprint_ratio": footprint["ratio"],
            # Exact only for single-K-tile projections (dims <= 256); the
            # full config reports the ULP-level tile-accumulation delta.
            "loss_bit_identity": losses["masked-dense"] == losses["compressed"],
            "loss_abs_delta": abs(losses["masked-dense"] - losses["compressed"]),
            "tokens_per_sec": {
                r["mode"]: r["tokens_per_sec"] for r in results
            },
        },
        "results": results,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=False)
        f.write("\n")
    print(f"wrote {out_path}")
    return doc


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model / few steps (CI regression gate)")
    ap.add_argument("--out", default="BENCH_train.json")
    ap.add_argument("--nm", default="t8:16")
    ap.add_argument("--reps", type=int, default=None)
    args = ap.parse_args()
    spec = PatternSpec.parse(args.nm)
    if not spec.transposable:
        ap.error(f"--nm must be transposable (got {spec}): compressed "
                 "execution needs one buffer for W and W^T — use "
                 f"'t{spec.n}:{spec.m}'")
    if args.smoke:
        doc = run(SMOKE_CFG, spec, seq=32, batch=4,
                  reps=args.reps or 2, solver_iters=40, out_path=args.out)
        head = doc["headline"]
        # Gate 1: the bench's bytes-moved ratio must track the analytic
        # compressed_bytes model within 10%.
        assert abs(head["bytes_ratio_bench"] - head["bytes_ratio_analytic"]) \
            <= 0.1 * head["bytes_ratio_analytic"], head
        # Gate 2: compressed execution is the dense path, bit for bit (the
        # smoke shapes are single-K-tile, where this holds exactly).
        assert head["loss_bit_identity"], doc["results"]
        # Gate 3: the actgrad term is mode-invariant, so the total ratio
        # must sit strictly between the weights-only ratio and 1.
        assert head["bytes_ratio_bench"] < head["bytes_ratio_total"] < 1.0, head
    else:
        doc = run(FULL_CFG, spec, seq=128, batch=8,
                  reps=args.reps or 5, solver_iters=150, out_path=args.out)
        # Multi-tile shapes: require agreement to float32-roundoff scale,
        # not bitwise (per-K-tile accumulation reorders the dense sum).
        assert doc["headline"]["loss_abs_delta"] < 1e-4, doc["headline"]


if __name__ == "__main__":
    main()
