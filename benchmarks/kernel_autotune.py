"""Roofline-guided tile autotune for the compressed hot-path kernels.

Drives :mod:`repro.perf.autotune` over the benched shape classes — the
prefill GEMM and decode GEMV operand shapes of the train/serve benchmarks
(forward and decompress-transpose nm_spmm products) plus the fused solver's
block-batch tile — measures the roofline-shortlisted candidates on the live
device, and writes:

* ``BENCH_kernels.json`` — per shape class: default tiles vs measured best,
  seconds, speedup, the full candidate timing table.  The fixed default
  tiles are always in the measured candidate set, so ``speedup_vs_default``
  is >= 1 by construction on the run that produced it; the decode GEMV must
  be *strictly* faster (the fixed bt=256 tile wastes 31/32 rows there).
* (``--table`` / ``--update-default``) the versioned tuning table the
  kernels consult at trace time (``repro.perf.table``), keyed by device
  kind, group size and shape class — tiles tuned on this container's CPU
  interpret mode never apply on a TPU and vice versa.

On CPU the Pallas kernels run in interpret mode, so absolute times measure
dispatch + per-element interpret cost, not TPU bandwidth — but the *ranking*
(and the decode-GEMV padding waste) is real on both: fewer padded rows is
less work everywhere.

Run:    PYTHONPATH=src:. python benchmarks/kernel_autotune.py --update-default
Smoke:  PYTHONPATH=src:. python benchmarks/kernel_autotune.py --smoke
"""
from __future__ import annotations

import argparse
import json
import pathlib
import platform

import jax

from benchmarks.common import emit
from repro.kernels import default_interpret
from repro.perf.autotune import (
    autotune_fused_solve,
    autotune_nm_sparsify,
    autotune_nm_spmm,
    autotune_nm_spmm_cc,
)
from repro.perf.table import TuningTable, default_table_path, device_kind_of

# Shape classes mirror BENCH_train.json's bench-30m (t8:16, seq 128, batch 8:
# prefill rows = 8*128, decode rows = 8, K = d_model, F = d_ff) and the
# solver bench's block batches.
FULL_CELLS = {
    "nm_spmm_fwd_gemm": dict(rows=1024, k=384, f=1536, n=8, m=16),
    "nm_spmm_tr_gemm": dict(rows=1024, k=384, f=1536, n=8, m=16, transpose=True),
    "nm_spmm_fwd_gemv": dict(rows=8, k=384, f=1536, n=8, m=16),
    "fused_solve_m16": dict(op="fused", m=16, n=8, batch=512, iters=40),
    # Structured-sparse backward (BENCH_backward.json shapes): 8:16 gradient
    # sparsify over the wide cotangent, and the compressed x compressed dX
    # GEMM at the ffn down-projection (the tall-K case the cc default row
    # tile targets) plus the d_model-K case.
    "nm_sparsify_gemm": dict(op="sparsify", rows=1024, f=1536, n=8, m=16),
    "nm_sparsify_narrow": dict(op="sparsify", rows=1024, f=384, n=8, m=16),
    "nm_spmm_cc_gemm": dict(op="cc", rows=1024, k=384, f=1536,
                            n_g=8, m_g=16, n_w=8, m_w=16),
    "nm_spmm_cc_tallk": dict(op="cc", rows=1024, k=1536, f=384,
                             n_g=8, m_g=16, n_w=8, m_w=16),
}
SMOKE_CELLS = {
    "nm_spmm_fwd_gemm": dict(rows=128, k=64, f=128, n=8, m=16),
    "nm_spmm_tr_gemm": dict(rows=128, k=64, f=128, n=8, m=16, transpose=True),
    "nm_spmm_fwd_gemv": dict(rows=8, k=64, f=128, n=8, m=16),
    "fused_solve_m8": dict(op="fused", m=8, n=4, batch=64, iters=10),
    "nm_sparsify_gemm": dict(op="sparsify", rows=128, f=128, n=8, m=16),
    "nm_spmm_cc_gemm": dict(op="cc", rows=128, k=64, f=128,
                            n_g=8, m_g=16, n_w=8, m_w=16),
}


def run(cells: dict, shape_set: str, reps: int, out_path: str,
        table_path: str | None) -> dict:
    results, headline = {}, {}
    for name, cell in cells.items():
        cell = dict(cell)
        op = cell.pop("op", None)
        if op == "fused":
            res = autotune_fused_solve(
                cell["m"], cell["n"], batch=cell["batch"],
                iters=cell["iters"], reps=reps,
            )
        elif op == "sparsify":
            res = autotune_nm_sparsify(reps=reps, **cell)
        elif op == "cc":
            res = autotune_nm_spmm_cc(reps=reps, **cell)
        else:
            res = autotune_nm_spmm(reps=reps, **cell)
        results[name] = res
        headline[name] = {
            "op": res.op,
            "shape": list(res.shape),
            "shape_class": res.shape_class,
            "default_tiles": list(res.default_tiles),
            "best_tiles": list(res.best_tiles),
            "default_seconds": res.default_seconds,
            "best_seconds": res.best_seconds,
            "speedup_vs_default": res.speedup_vs_default,
        }
        emit(f"autotune_{name}", res.best_seconds,
             f"best={res.best_tiles} default={res.default_tiles} "
             f"speedup={res.speedup_vs_default:.2f}x")

    doc = {
        "meta": {
            "benchmark": "kernel_autotune",
            "platform": platform.platform(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "device": device_kind_of(),
            "interpret_mode": default_interpret(),
            "shape_set": shape_set,
            "reps": reps,
        },
        "headline": headline,
        "results": {name: res.to_json() for name, res in results.items()},
    }
    out = pathlib.Path(out_path)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {out}")

    if table_path:
        path = pathlib.Path(table_path)
        try:
            table = TuningTable.load(path)
        except FileNotFoundError:
            table = TuningTable()
        for res in results.values():
            table.put(res.table_entry())
        table.save(path)
        print(f"wrote {path} ({len(table)} entries)")
    return doc


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few reps (CI gate)")
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--table", default=None, metavar="PATH",
                    help="write/merge the tuning table here")
    ap.add_argument("--update-default", action="store_true",
                    help="write winners into the packaged default table "
                         f"({default_table_path()})")
    args = ap.parse_args()
    table_path = args.table or (
        str(default_table_path()) if args.update_default else None
    )
    cells = SMOKE_CELLS if args.smoke else FULL_CELLS
    shape_set = "smoke" if args.smoke else "full"
    doc = run(cells, shape_set, args.reps or (2 if args.smoke else 3),
              args.out, table_path)

    # Gates (always-on: the committed BENCH must satisfy them too).
    head = doc["headline"]
    worst = min(c["speedup_vs_default"] for c in head.values())
    assert worst >= 1.0, f"autotuned tiles slower than default: {head}"
    decode = head["nm_spmm_fwd_gemv"]["speedup_vs_default"]
    assert decode > 1.0, (
        f"decode GEMV not strictly faster than the fixed tiles: {decode}"
    )
    print(f"gates OK: min speedup {worst:.2f}x, decode GEMV {decode:.2f}x")


if __name__ == "__main__":
    main()
