"""Structured-sparse backward bench: bytes model vs kernel config, tok/s, grad error.

Benches the ``grad_sparsity`` backward path (``repro.kernels.nm_grad``) on the
bench-30m model and writes ``BENCH_backward.json`` with three ingredients:

* **Backward bytes, model vs measured** — per compressed projection, the
  :func:`repro.perf.roofline.nm_grad_cost` HBM-traffic model (sparse-cotangent
  path vs the PR-9 dense-cotangent path) evaluated at the tiles each kernel
  *actually resolves* at trace time, against an independent re-accounting of
  the same traffic from the kernels' own tile resolvers and concrete padded
  buffer sizes.  The two agree exactly today; the 5% gate is a tripwire that
  fires when a kernel's grid/tile logic and the roofline formulas drift apart.
  Headline: ``bytes_ratio_model = sparse/dense`` aggregated over every
  projection x layer, gated <= 0.8 at 8:16 grads.
* **tok/s, dense-grad vs sparse-grad** — one optimizer step of the same
  compressed model with ``grad_sparsity="off"`` vs ``"8:16"``.  On this CPU
  container the Pallas kernels run in interpret mode, so the sparse-grad step
  pays three kernel dispatches per projection (sparsify + cc-GEMM + dW spmm)
  where the dense-grad step pays one; the gate is against the *committed PR-9
  compressed baseline* (a literal below), not the same-run dense-grad number.
* **Per-layer gradient error** — relative L2 of each projection's ``values``
  cotangent, sparse-grad vs exact, one batch.  MVU rounding is elementwise
  unbiased but not variance-free: ~2x relative error per sparsification for
  near-uniform block magnitudes at 8:16, cascading a few-fold by the first
  layer (every downstream dX hop is sparsified too).  The forward loss stays
  bit-identical — sparsification touches only the backward.

Run:    PYTHONPATH=src:. python benchmarks/backward_sparse.py
Smoke:  PYTHONPATH=src:. python benchmarks/backward_sparse.py --smoke
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.api import PatternSpec, SolverConfig
from repro.data import SyntheticLM
from repro.kernels import default_interpret
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import AdamW
from repro.perf.roofline import nm_grad_cost
from repro.sparsity.masks import apply_mask, sparsify_pytree
from repro.sparsity.params import NMCompressed, compress_params, projection_prunable
from repro.train import build_train_step, make_train_state
from repro.train.step import StepConfig
from repro.treepath import path_entry_str

SMOKE_CFG = ModelConfig("bench-smoke", "dense", num_layers=2, d_model=64,
                        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                        remat="none", dtype="float32")
FULL_CFG = ModelConfig("bench-30m", "dense", num_layers=6, d_model=384,
                       num_heads=6, num_kv_heads=2, d_ff=1536, vocab_size=8192,
                       remat="none", dtype="float32")

# PR 9's committed compressed-mode throughput (BENCH_train.json headline,
# commit 91f2dcd) — the acceptance floor for the end-to-end sparse-grad step.
# Kept as a literal rather than read back from BENCH_train.json: regenerating
# that file on a quieter container would silently move the goalpost.
PR9_COMPRESSED_TOK_S = 80.74


def _round_up(x: int, a: int) -> int:
    return -(-x // a) * a


def _resolved_tiles(rows: int, k: int, f: int, m_g: int, m_w: int):
    """The tiles every backward kernel resolves for this projection shape —
    sparsify, cc dX GEMM, dW spmm (streams Xᵀ: K rows, reduction over the
    m_g-padded token rows), and the dense path's transpose spmm."""
    from repro.kernels.nm_grad.kernel import (
        _resolve_cc_tiles,
        _resolve_sparsify_tiles,
    )
    from repro.kernels.nm_spmm.kernel import _resolve_tiles

    rp = _round_up(rows, m_g)
    return {
        "sparsify": _resolve_sparsify_tiles(rows, f, m_g, None, None),
        "cc": _resolve_cc_tiles(rows, k, f, m_g, m_w, None, None, None),
        "dw": _resolve_tiles(k, rp, f, m_g, False, None, None, None),
        "tr": _resolve_tiles(rows, k, f, m_w, True, None, None, None),
    }


def _measured_bytes(rows: int, k: int, f: int, n_g: int, m_g: int,
                    n_w: int, m_w: int, tiles: dict, g_itemsize: int) -> dict:
    """Backward HBM traffic re-accounted from the kernels' actual launch
    configuration: the trace-time resolved tiles (table lookups + clamping
    included) and the concrete padded buffer sizes they imply, with each
    operand's revisit count read off the kernels' BlockSpec index maps."""
    gb = g_itemsize + 1          # compressed dY: values + int8 index
    wb = 4 + 1                   # compressed W: f32 values + int8 index

    # Sparsify: one pass, dY read once, compressed buffer written once.
    sbt, sft = tiles["sparsify"]
    pr, pfs = _round_up(rows, sbt), _round_up(f, sft)
    sparsify = pr * pfs * 4 + (pr // m_g) * n_g * pfs * gb

    # cc dX: grid (B/bt, K/kt, F/ft); the dY block row is re-read once per
    # K tile, the W block row once per B tile, the output written once.
    cbt, ckt, cft = tiles["cc"]
    pb, pk, pf = _round_up(rows, cbt), _round_up(k, ckt), _round_up(f, cft)
    g_buf = (pb // m_g) * n_g * pf * gb
    w_buf = (pk // m_w) * n_w * pf * wb
    dx_sparse = (pk // ckt) * g_buf + (pb // cbt) * w_buf + pb * pk * 4

    # dW spmm: Xᵀ streamed (re-read per F tile), compressed dY re-read per
    # output-row tile, output written once.
    wbt, wkt, wft = tiles["dw"]
    rp = _round_up(rows, m_g)
    pkw, prw, pfw = _round_up(k, wbt), _round_up(rp, wkt), _round_up(f, wft)
    x_dw = (pfw // wft) * pkw * prw * 4
    g_dw = (pkw // wbt) * (prw // m_g) * n_g * pfw * gb
    out_dw = pkw * pfw * 4
    gather = k * f * 4 + (k // m_w) * n_w * f * 4   # support gather, both paths

    # Dense-cotangent path: dX through the transpose spmm (dense dY re-read
    # per K tile), dW as a dense GEMM at the dW-spmm tiling.
    tbt, tkt, tft = tiles["tr"]
    pbd, pkd, pfd = _round_up(rows, tbt), _round_up(k, tkt), _round_up(f, tft)
    dx_dense = ((pkd // tkt) * pbd * pfd * 4
                + (pbd // tbt) * (pkd // m_w) * n_w * pfd * wb
                + pbd * pkd * 4)
    dw_dense = x_dw + (pkw // wbt) * prw * pfw * 4 + out_dw

    sparse = sparsify + dx_sparse + (x_dw + g_dw + out_dw) + gather
    dense = dx_dense + dw_dense + gather
    return {"sparse_bytes": sparse, "dense_bytes": dense}


def _projections(sp) -> list[dict]:
    """Every compressed projection in the tree: name, (K, F), layer count."""
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(
        sp, is_leaf=lambda x: isinstance(x, NMCompressed)
    )[0]:
        if not isinstance(leaf, NMCompressed):
            continue
        shape = leaf.dense_shape
        out.append({
            "name": path_entry_str(path[-1]) if path else "?",
            "k": int(shape[-2]),
            "f": int(shape[-1]),
            "layers": int(np.prod(shape[:-2])) if len(shape) > 2 else 1,
            "n_w": leaf.n,
            "m_w": leaf.m,
        })
    return out


def _bytes_section(sp, rows: int, gspec: PatternSpec, g_itemsize: int) -> dict:
    per_proj = []
    model_sp = model_dn = meas_sp = meas_dn = 0
    for p in _projections(sp):
        tiles = _resolved_tiles(rows, p["k"], p["f"], gspec.m, p["m_w"])
        model = nm_grad_cost(
            rows, p["k"], p["f"], gspec.n, gspec.m, p["n_w"], p["m_w"],
            g_val_bytes=g_itemsize,
            sparsify_tiles=tiles["sparsify"], cc_tiles=tiles["cc"],
            spmm_tiles=tiles["dw"], tr_tiles=tiles["tr"],
        )
        meas = _measured_bytes(rows, p["k"], p["f"], gspec.n, gspec.m,
                               p["n_w"], p["m_w"], tiles, g_itemsize)
        model_sp += p["layers"] * model["sparse_bytes"]
        model_dn += p["layers"] * model["dense_bytes"]
        meas_sp += p["layers"] * meas["sparse_bytes"]
        meas_dn += p["layers"] * meas["dense_bytes"]
        per_proj.append({
            **{k: p[k] for k in ("name", "k", "f", "layers")},
            "tiles": {k: list(v) for k, v in tiles.items()},
            "model": model,
            "measured": meas,
            "ratio_model": model["ratio"],
        })
    err = max(abs(meas_sp - model_sp) / model_sp,
              abs(meas_dn - model_dn) / model_dn)
    return {
        "per_projection": per_proj,
        "model": {"sparse_bytes": model_sp, "dense_bytes": model_dn},
        "measured": {"sparse_bytes": meas_sp, "dense_bytes": meas_dn},
        "bytes_ratio_model": model_sp / model_dn,
        "bytes_ratio_measured": meas_sp / meas_dn,
        "model_measured_err": err,
    }


def _grad_error(sp, cfg: ModelConfig, batch: dict, gspec: PatternSpec) -> dict:
    """Per-layer relative L2 error of each projection's values-cotangent,
    sparse-grad vs exact, plus the global all-leaf relative error."""
    from repro.kernels.nm_grad.ops import sparse_grad_context

    def loss(p):
        return lm.loss_fn(p, cfg, batch)

    g_exact = jax.grad(loss, allow_int=True)(sp)
    with sparse_grad_context(gspec, 0):
        g_sparse = jax.grad(loss, allow_int=True)(sp)

    flat_e = jax.tree_util.tree_flatten_with_path(g_exact)[0]
    flat_s = {tuple(map(str, p)): v
              for p, v in jax.tree_util.tree_flatten_with_path(g_sparse)[0]}
    per_layer: dict[str, list[float]] = {}
    num = den = 0.0
    for path, ge in flat_e:
        if ge.dtype == jax.dtypes.float0 or ge.size == 0:
            continue
        gs = flat_s[tuple(map(str, path))]
        d = np.asarray(gs, np.float64) - np.asarray(ge, np.float64)
        num += float((d * d).sum())
        den += float((np.asarray(ge, np.float64) ** 2).sum())
        if path_entry_str(path[-1]) != "values":
            continue
        name = ".".join(path_entry_str(e) for e in path[-3:-1]) or "proj"
        e_np, s_np = np.asarray(ge, np.float64), np.asarray(gs, np.float64)
        if e_np.ndim <= 3:          # single layer
            e_np, s_np = e_np[None], s_np[None]
        else:                       # stacked (L, G, N, F)
            e_np = e_np.reshape(-1, *e_np.shape[-3:])
            s_np = s_np.reshape(-1, *s_np.shape[-3:])
        errs = [
            float(np.linalg.norm(s_np[i] - e_np[i])
                  / max(np.linalg.norm(e_np[i]), 1e-30))
            for i in range(e_np.shape[0])
        ]
        per_layer[name] = errs
    proj_max = max((e for v in per_layer.values() for e in v), default=0.0)
    return {
        "per_layer": per_layer,
        "proj_rel_err_max": proj_max,
        "global_rel_err": float(np.sqrt(num / max(den, 1e-30))),
    }


def _time_steps(step_fn, state, batches, reps: int) -> tuple[float, float]:
    state, metrics = step_fn(state, batches[0])
    first_loss = float(np.asarray(metrics["loss"]))
    ts = []
    for r in range(reps):
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batches[(r + 1) % len(batches)])
        jax.block_until_ready(metrics["loss"])
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), first_loss


def run(cfg: ModelConfig, wspec: PatternSpec, gspec: PatternSpec, seq: int,
        batch: int, reps: int, solver_iters: int, out_path: str) -> dict:
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq,
                       global_batch=batch)
    batches = [{k: jnp.asarray(v) for k, v in data.batch(i).items()}
               for i in range(max(2, reps))]
    rows = seq * batch

    params = jax.block_until_ready(lm.init_params(cfg, jax.random.PRNGKey(0)))
    masks = sparsify_pytree(params, wspec,
                            config=SolverConfig(iters=solver_iters),
                            prunable=projection_prunable)
    sp = compress_params(apply_mask(params, masks), masks, wspec)
    opt = AdamW(learning_rate=1e-3, clip_norm=0.0)

    g_itemsize = jnp.dtype(jnp.bfloat16).itemsize  # sparse_grad_context default
    bytes_doc = _bytes_section(sp, rows, gspec, g_itemsize)
    emit("backward_bytes_ratio", 0.0,
         f"model={bytes_doc['bytes_ratio_model']:.4f} "
         f"measured={bytes_doc['bytes_ratio_measured']:.4f} "
         f"err={bytes_doc['model_measured_err']:.4f}")

    modes = {
        "dense-grad": StepConfig(mask_mode="compressed"),
        "sparse-grad": StepConfig(mask_mode="compressed",
                                  grad_sparsity=str(gspec)),
    }
    tok_s, losses = {}, {}
    for mode, scfg in modes.items():
        state = make_train_state(cfg, opt, jax.random.PRNGKey(1), params=sp)
        step = build_train_step(cfg, opt, step_cfg=scfg, donate=False)
        sec, loss = _time_steps(step, state, batches, reps)
        tok_s[mode] = rows / sec
        losses[mode] = loss
        emit(f"backward_step_{mode}", sec, f"tok/s={tok_s[mode]:.0f}")

    grad_doc = _grad_error(sp, cfg, batches[0], gspec)
    emit("backward_grad_err", 0.0,
         f"proj_max={grad_doc['proj_rel_err_max']:.3f} "
         f"global={grad_doc['global_rel_err']:.3f}")

    doc = {
        "meta": {
            "benchmark": "backward_sparse",
            "platform": platform.platform(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "device": str(jax.local_devices()[0].device_kind),
            "interpret_mode": default_interpret(),
            "model": cfg.name,
            "pattern": str(wspec),
            "grad_pattern": str(gspec),
            "grad_dtype": "bfloat16",
            "seq_len": seq,
            "batch": batch,
            "reps": reps,
        },
        "headline": {
            "bytes_ratio_model": bytes_doc["bytes_ratio_model"],
            "bytes_ratio_measured": bytes_doc["bytes_ratio_measured"],
            "model_measured_err": bytes_doc["model_measured_err"],
            "tokens_per_sec": tok_s,
            "pr9_compressed_tok_s": PR9_COMPRESSED_TOK_S,
            "sparse_vs_pr9": tok_s["sparse-grad"] / PR9_COMPRESSED_TOK_S,
            # Sparsification touches only the backward: the forward (and so
            # the first-step loss) must match the dense-grad step bitwise.
            "forward_bit_identity": losses["dense-grad"] == losses["sparse-grad"],
            "grad_rel_err_max": grad_doc["proj_rel_err_max"],
            "grad_rel_err_global": grad_doc["global_rel_err"],
        },
        "bytes": bytes_doc,
        "grad_error": grad_doc,
        "first_step_loss": losses,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"wrote {out_path}")
    return doc


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model / few steps (CI regression gate)")
    ap.add_argument("--out", default="BENCH_backward.json")
    ap.add_argument("--nm", default="t8:16", help="weight pattern")
    ap.add_argument("--grad-nm", default="8:16", help="gradient pattern")
    ap.add_argument("--reps", type=int, default=None)
    args = ap.parse_args()
    wspec = PatternSpec.parse(args.nm)
    gspec = PatternSpec.parse(args.grad_nm)
    if not wspec.transposable:
        ap.error(f"--nm must be transposable (got {wspec})")

    if args.smoke:
        doc = run(SMOKE_CFG, wspec, gspec, seq=32, batch=4,
                  reps=args.reps or 2, solver_iters=40, out_path=args.out)
    else:
        doc = run(FULL_CFG, wspec, gspec, seq=128, batch=8,
                  reps=args.reps or 5, solver_iters=150, out_path=args.out)
    head = doc["headline"]

    # Gate 1: the traffic accounting reconstructed from the kernels' actual
    # launch configuration must track the roofline model within 5%.
    assert head["model_measured_err"] <= 0.05, head
    # Gate 2: grad sparsification must not touch the forward.
    assert head["forward_bit_identity"], doc["first_step_loss"]
    # Gate 3: the MVU noise stays at its analytic scale.  For near-uniform
    # block magnitudes a, 8:16 MVU keeps the top 7 exactly and one stochastic
    # survivor carries the residual mass S = 9a, so the per-block error
    # variance sum_j a_j(S - a_j) ~ 72 a^2 against signal 16 a^2 — relative
    # error ~2.1 per sparsification.  The per-LAYER error cascades: layer i's
    # cotangent has passed through every downstream layer's sparsified dX
    # hop, so the first layers sit a few-fold above the single-hop scale
    # (bench-30m: ~6x at layer 0 vs ~1.4x at layer 5).  Well above 10 means
    # selection or rescaling broke, not sampling noise.
    assert head["grad_rel_err_max"] < 10.0, doc["grad_error"]
    if not args.smoke:
        # Gate 4 (full shapes only — tiny smoke shapes are padding-bound):
        # 8:16 sparse cotangents must save >= 20% backward bytes...
        assert head["bytes_ratio_model"] <= 0.8, head
        # ...and the end-to-end sparse-grad step must beat the committed
        # PR-9 compressed throughput.
        assert head["tokens_per_sec"]["sparse-grad"] >= PR9_COMPRESSED_TOK_S, head
    print(f"gates OK: bytes ratio {head['bytes_ratio_model']:.3f}, "
          f"model-vs-measured err {head['model_measured_err']:.4f}, "
          f"sparse-grad {head['tokens_per_sec']['sparse-grad']:.1f} tok/s "
          f"(floor {PR9_COMPRESSED_TOK_S})")


if __name__ == "__main__":
    main()
