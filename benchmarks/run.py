"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Mapping (DESIGN.md §6):
  solver_quality     -> Fig. 3   (rel. error vs exact across N:M)
  rounding_ablation  -> Fig. 6   (simple/greedy/optround x direct/entropy)
  solver_runtime     -> Tab. 1/3 (runtime scaling; CPU columns)
  reconstruction     -> Tab. 4   (layer-wise error, std vs transposable)
  pruning_quality    -> Tab. 2   (end-to-end one-shot pruning, miniature)
  finetune_recovery  -> Fig. 5   (sparse fine-tuning recovery)
  spmm_traffic       -> Fig. 4   (TPU bandwidth model + kernel check)
  service_throughput -> system   (bucketed MaskService vs per-tensor loop)
"""
from __future__ import annotations

import time
import traceback


def main() -> None:
    from benchmarks import (
        finetune_recovery,
        pruning_quality,
        reconstruction,
        rounding_ablation,
        service_throughput,
        solver_quality,
        solver_runtime,
        spmm_traffic,
    )

    print("name,us_per_call,derived")
    for mod in (
        solver_quality,
        rounding_ablation,
        solver_runtime,
        reconstruction,
        pruning_quality,
        finetune_recovery,
        spmm_traffic,
        service_throughput,
    ):
        t0 = time.time()
        try:
            mod.run()
            print(f"bench_{mod.__name__.split('.')[-1]}_wall,"
                  f"{(time.time() - t0) * 1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            print(f"bench_{mod.__name__.split('.')[-1]}_wall,"
                  f"{(time.time() - t0) * 1e6:.0f},ERROR:{type(e).__name__}")


if __name__ == "__main__":
    main()
