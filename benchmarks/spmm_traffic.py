"""Paper Fig. 4 (lower), TPU version: compressed N:M matmul HBM-traffic model
and projected speedups for memory-bound shapes (decode GEMV), from the
nm_spmm kernel's format accounting + an interpret-mode correctness spot-check.

The MXU has no sparse mode, so on TPU the N:M speedup is a *bandwidth* story:
speedup(mem-bound) ~= dense_bytes / (vals + idx bytes); transposable masks
additionally serve W^T from the same buffer (no re-compression for backward).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.api import PatternSpec, solve_mask
from repro.kernels.nm_spmm.kernel import nm_spmm_pallas
from repro.kernels.nm_spmm.ref import nm_spmm_ref
from repro.sparsity.compressed import compress_nm, compressed_bytes

PATTERNS = [(2, 4), (4, 8), (8, 16), (16, 32), (2, 8), (4, 16), (8, 32)]


def run():
    k = f = 4096
    for n, m in PATTERNS:
        acc = compressed_bytes(k, f, n, m, bytes_w=2)
        speedup = acc["dense"] / acc["compressed"]
        emit(
            f"spmm_traffic_{n}:{m}",
            0.0,
            f"ratio={acc['ratio']:.4f};membound_speedup={speedup:.2f}x",
        )
    # Correctness spot check of the kernel path used for the claim.
    rng = np.random.default_rng(0)
    w = rng.normal(size=(128, 128)).astype(np.float32)
    mask = np.array(solve_mask(jnp.asarray(w), PatternSpec(8, 16)))
    vals, idx = compress_nm(jnp.asarray(w), jnp.asarray(mask), 8, 16)
    x = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
    err_f = float(jnp.max(jnp.abs(
        nm_spmm_pallas(x, vals, idx, 16, bt=8, kt=64, ft=64)
        - nm_spmm_ref(x, vals, idx, 16))))
    g = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
    err_t = float(jnp.max(jnp.abs(
        nm_spmm_pallas(g, vals, idx, 16, transpose=True, bt=8, kt=64, ft=64)
        - nm_spmm_ref(g, vals, idx, 16, transpose=True))))
    emit("spmm_kernel_check", 0.0, f"fwd_err={err_f:.2e};bwd_err={err_t:.2e}")


if __name__ == "__main__":
    run()
