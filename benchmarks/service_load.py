"""Mask-server load test: multi-tenant latency, fairness, shared cache.

Boots a :class:`repro.service.net.MaskServer` (in-process threads by
default; ``--spawn`` execs the real ``repro.launch.serve_masks`` CLI as a
subprocess and talks to it over TCP, which is what the CI service job runs)
and drives it with concurrent :class:`MaskClient` tenants:

* **sanity** — one tensor solved over the wire must be bit-identical to an
  in-process ``MaskService.solve`` under the same config (tol = 0).
* **adversarial skew** — a flooding "heavy" tenant (many mixed-shape,
  mixed-pattern submits, eager fan-out from several threads) races an
  "interactive" tenant submitting a trickle.  Per-tenant p50/p99 *server*
  latency (enqueue -> solve, from the wait replies) and blocks/sec come
  out per tenant; the starvation gate holds the interactive tenant's p99
  well under the makespan — under a starving scheduler (plain FIFO over
  one queue) every interactive request would resolve only after the whole
  flood, pushing its p99 to ~1.0 of makespan.
* **shared cache tier** — a third tenant replays the heavy tenant's
  tensors byte-identical; every one must be a server-side cache hit
  (hit rate > 0 is the issue's acceptance gate; we assert 100%).
* **fairness** — ``max/min`` across tenants of quota-normalized
  blocks/sec, over the window where both are backlogged.

Writes ``BENCH_service.json``; ``--smoke`` shrinks the workload and turns
the gates into hard asserts for CI.

Run:    PYTHONPATH=src:. python benchmarks/service_load.py
Smoke:  PYTHONPATH=src:. python benchmarks/service_load.py --smoke
"""
from __future__ import annotations

import argparse
import json
import platform
import re
import subprocess
import sys
import threading
import time
from contextlib import contextmanager

import numpy as np

from benchmarks.common import emit
from repro.api import MaskService, PatternSpec, SolverConfig
from repro.service.net import MaskClient, MaskServer, TenantConfig

PATTERNS = [PatternSpec(4, 8), PatternSpec(2, 4)]


def workload(n_tensors: int, seed: int, max_side: int = 48):
    """Mixed shapes and patterns; returns (name, w, pattern) triples."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_tensors):
        spec = PATTERNS[i % len(PATTERNS)]
        r = int(rng.integers(1, max_side // spec.m + 1)) * spec.m
        c = int(rng.integers(1, max_side // spec.m + 1)) * spec.m
        out.append((f"w{seed}-{i}", rng.normal(size=(r, c)).astype(np.float32),
                    spec))
    return out


@contextmanager
def serve(args, solver: SolverConfig):
    """Yield a server address: in-process threads, or the real CLI."""
    if not args.spawn:
        server = MaskServer(
            MaskService(solver),
            tenants={
                "heavy": TenantConfig(quota=1.0),
                "interactive": TenantConfig(quota=1.0),
            },
            round_blocks=args.round_blocks,
            batch_window_s=0.002,
        )
        with server:
            yield server.address
        return
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve_masks", "--port", "0",
         "--iters", str(solver.iters),
         "--round-blocks", str(args.round_blocks),
         "--tenant", "heavy:quota=1", "--tenant", "interactive:quota=1"],
        stdout=subprocess.PIPE, text=True,
    )
    try:
        line = proc.stdout.readline()
        m = re.search(r"listening on (\S+:\d+)", line)
        assert m, f"serve-masks did not report an address: {line!r}"
        yield m.group(1)
    finally:
        try:
            with MaskClient(m.group(1), tenant="ops") as c:
                c.shutdown_server()
        except Exception:  # noqa: BLE001 — already dead is fine
            proc.kill()
        proc.wait(timeout=30)


def _percentiles(xs):
    xs = [x for x in xs if x is not None]
    if not xs:
        return {"p50": None, "p99": None, "mean": None, "n": 0}
    return {
        "p50": float(np.percentile(xs, 50)),
        "p99": float(np.percentile(xs, 99)),
        "mean": float(np.mean(xs)),
        "n": len(xs),
    }


def run(args) -> dict:
    solver = SolverConfig(iters=40 if args.smoke else 100)
    heavy_n = 48 if args.smoke else 600
    light_n = 8 if args.smoke else 60
    heavy_threads = 4 if args.smoke else 8

    with serve(args, solver) as address:
        # -- warm the solver's jit cache so latency measures scheduling,
        # not once-per-process compilation.
        with MaskClient(address, tenant="warm") as c:
            for name, w, spec in workload(2 * len(PATTERNS), seed=99):
                c.submit(name, w, spec, journal=False)
            c.flush()

        # -- sanity: wire solve == local solve, bit for bit ---------------
        rng = np.random.default_rng(0)
        w0 = rng.normal(size=(32, 16)).astype(np.float32)
        with MaskClient(address, tenant="warm") as c:
            remote = np.asarray(c.solve(w0, "t4:8"))
        local = np.asarray(MaskService(solver).solve(w0, "t4:8"))
        bit_identical = bool(np.array_equal(remote, local))
        assert bit_identical, "remote mask diverged from in-process solve"

        # -- adversarial skew: flood vs trickle, concurrently -------------
        heavy_items = workload(heavy_n, seed=1)
        light_items = workload(light_n, seed=2, max_side=24)
        heavy_blocks = sum(
            (w.shape[0] // s.m) * (w.shape[1] // s.m)
            for _, w, s in heavy_items
        )
        light_blocks = sum(
            (w.shape[0] // s.m) * (w.shape[1] // s.m)
            for _, w, s in light_items
        )
        lat = {"heavy": [], "interactive": []}
        wall = {"heavy": [], "interactive": []}
        done_at = {}
        errors = []
        t_start = time.monotonic()

        def heavy_tenant(tid, items):
            try:
                with MaskClient(address, tenant="heavy") as c:
                    handles = [c.submit(f"{tid}/{n}", w, s, journal=False)
                               for n, w, s in items]
                    c.flush()
                    lat["heavy"].extend(
                        h.server_latency_s for h in handles)
                    wall["heavy"].append(time.monotonic() - t_start)
                    done_at["heavy"] = time.monotonic()
            except BaseException as e:  # noqa: BLE001
                errors.append(e)

        def interactive_tenant():
            try:
                with MaskClient(address, tenant="interactive") as c:
                    for n, w, s in light_items:
                        t0 = time.monotonic()
                        h = c.submit(n, w, s, journal=False)
                        c.flush()
                        assert h.done
                        wall["interactive"].append(time.monotonic() - t0)
                        lat["interactive"].append(h.server_latency_s)
                    done_at["interactive"] = time.monotonic()
            except BaseException as e:  # noqa: BLE001
                errors.append(e)

        chunks = np.array_split(np.arange(len(heavy_items)), heavy_threads)
        threads = [
            threading.Thread(target=heavy_tenant,
                             args=(t, [heavy_items[i] for i in idx]))
            for t, idx in enumerate(chunks)
        ] + [threading.Thread(target=interactive_tenant)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors
        makespan = time.monotonic() - t_start

        # -- shared cache tier: replay the heavy tenant's tensors ---------
        with MaskClient(address, tenant="replay") as c:
            replayed = [c.submit(f"replay/{n}", w, s, journal=False)
                        for n, w, s in heavy_items]
            c.flush()
            assert all(h.done for h in replayed)
            stats = c.server_stats()

        rows = stats["tenants"]
        replay_hits = rows["replay"]["cache_hits"]
        replay_rate = replay_hits / max(1, rows["replay"]["submitted"])
        tput = {}
        for name, blocks in (("heavy", heavy_blocks),
                             ("interactive", light_blocks)):
            window = done_at[name] - t_start
            tput[name] = blocks / window / rows[name]["quota"]
        fairness = max(tput.values()) / max(min(tput.values()), 1e-9)

        heavy_p = _percentiles(lat["heavy"])
        light_p = _percentiles(lat["interactive"])
        starvation_frac = (light_p["p99"] or makespan) / makespan

    emit("service_load_makespan", makespan,
         f"heavy={heavy_blocks}b interactive={light_blocks}b "
         f"p99(heavy)={heavy_p['p99']:.3f}s p99(light)={light_p['p99']:.3f}s")
    emit("service_load_shared_cache", replay_rate,
         f"{replay_hits}/{rows['replay']['submitted']} replayed submits hit")

    doc = {
        "meta": {
            "benchmark": "service_load",
            "platform": platform.platform(),
            "python": platform.python_version(),
            "smoke": args.smoke,
            "spawned_cli": args.spawn,
            "solver_iters": solver.iters,
            "round_blocks": args.round_blocks,
            "heavy_submits": heavy_n,
            "heavy_threads": heavy_threads,
            "interactive_submits": light_n,
        },
        "headline": {
            "bit_identical": bit_identical,
            "makespan_seconds": makespan,
            "blocks_per_sec_total": (
                stats["service"]["blocks_solved"]
                / max(stats["service"]["solve_seconds"], 1e-9)
            ),
            "interactive_p99_over_makespan": starvation_frac,
            "fairness_max_over_min": fairness,
            "replay_cache_hit_rate": replay_rate,
            "service_cache_hits": stats["service"]["cache_hits"],
            "service_dedup_hits": stats["service"]["dedup_hits"],
            "scheduler_rounds": stats["rounds"],
        },
        "tenants": {
            name: {
                **rows[name],
                "server_latency": (_percentiles(lat[name])
                                   if name in lat else None),
                "client_wall": (_percentiles(wall[name])
                                if name in wall else None),
                "quota_norm_blocks_per_sec": tput.get(name),
            }
            for name in sorted(rows)
        },
    }

    if args.smoke:
        # The issue's acceptance gates, as hard asserts for CI.
        assert replay_rate > 0, "second tenant saw no shared-cache hits"
        assert replay_hits == len(heavy_items), (
            f"replay should be all cache hits, got {replay_hits}")
        for name in ("heavy", "interactive", "replay"):
            assert rows[name]["resolved"] == rows[name]["submitted"], (
                f"tenant {name} lost requests: {rows[name]}")
        assert starvation_frac < 0.9, (
            f"interactive tenant starved: p99 at {starvation_frac:.2f} "
            "of makespan")
        print("SMOKE OK: shared cache + no starvation under skew")
    return doc


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small workload + hard CI gates")
    ap.add_argument("--spawn", action="store_true",
                    help="boot the real serve-masks CLI as a subprocess")
    ap.add_argument("--round-blocks", type=int, default=256)
    ap.add_argument("--out", default="BENCH_service.json")
    args = ap.parse_args()
    doc = run(args)
    doc["meta"]["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                             time.gmtime())
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
