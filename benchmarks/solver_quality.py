"""Paper Fig. 3: relative error vs the exact optimum across N:M patterns.

Methods: TSENOR (full), Entropy+simple-round, 2-Approximation, Bi-NM, MaxK.
Oracle: per-block LP (integral by matching-polytope theory).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import (
    SolverConfig,
    dykstra_log,
    objective,
    simple_round,
    solve_blocks,
)
from repro.core.baselines import bi_nm, max_k_random, two_approx
from repro.core.exact import lp_exact

PATTERNS = [(2, 4), (4, 8), (2, 8), (8, 16), (4, 16), (16, 32), (8, 32)]
BLOCKS = 24


def rel_errors(masks, w, opts):
    vals = np.array([float(objective(masks[i], w[i])) for i in range(len(w))])
    return float(np.mean((opts - vals) / opts))


def run():
    rng = np.random.default_rng(0)
    for n, m in PATTERNS:
        w = np.abs(rng.normal(size=(BLOCKS, m, m))).astype(np.float32)
        wj = jnp.asarray(w)
        opts = np.array([lp_exact(b, n)[1] for b in w])

        results = {
            "tsenor": solve_blocks(wj, n, SolverConfig(iters=300)),
            "entropy_simple": simple_round(dykstra_log(wj, n, iters=300), n),
            "2approx": two_approx(wj, n),
            "binm": bi_nm(wj, n),
            "max1000": max_k_random(jax.random.PRNGKey(0), wj, n, k=1000),
        }
        for name, masks in results.items():
            err = rel_errors(np.array(masks), w, opts)
            emit(f"quality_{n}:{m}_{name}", 0.0, f"rel_err={err:.5f}")


if __name__ == "__main__":
    run()
