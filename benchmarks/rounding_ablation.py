"""Paper §B.2.1 / Fig. 6: rounding ablation.

Simple / Greedy / Optround(greedy+local-search), each applied to (a) raw |W|
and (b) the entropy-regularized Dykstra solution.  Claims validated: greedy
cuts error vs simple; local search cuts it further (~50%); rounding the
entropy solution beats rounding |W| directly.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import dykstra_log, greedy_round, local_search, objective, simple_round
from repro.core.exact import lp_exact

PATTERNS = [(4, 8), (8, 16), (16, 32)]
BLOCKS = 16


def run():
    rng = np.random.default_rng(1)
    for n, m in PATTERNS:
        w = np.abs(rng.normal(size=(BLOCKS, m, m))).astype(np.float32)
        wj = jnp.asarray(w)
        opts = np.array([lp_exact(b, n)[1] for b in w])
        entropy = dykstra_log(wj, n, iters=300)

        def err(masks):
            vals = np.array([float(objective(masks[i], w[i])) for i in range(BLOCKS)])
            return float(np.mean((opts - vals) / opts))

        cases = {
            "direct_simple": simple_round(wj, n),
            "direct_greedy": greedy_round(wj, n),
            "direct_optround": local_search(greedy_round(wj, n), wj, n, 10),
            "entropy_simple": simple_round(entropy, n),
            "entropy_greedy": greedy_round(entropy, n),
            "entropy_optround": local_search(greedy_round(entropy, n), wj, n, 10),
        }
        for name, masks in cases.items():
            emit(f"ablation_{n}:{m}_{name}", 0.0, f"rel_err={err(np.array(masks)):.5f}")


if __name__ == "__main__":
    run()
