"""Paper Tab. 1/3: solver runtime scaling with matrix size (CPU here; the
GPU/TPU columns of the paper become the roofline analysis of the Pallas
kernels in EXPERIMENTS.md §Roofline).

Rows: full TSENOR (XLA path), Dykstra only, rounding only, 2-Approximation,
Bi-NM — per matrix size, transposable 8:16.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import block, emit, timeit
from repro.core import SolverConfig, dykstra_log, solve_blocks
from repro.core.baselines import bi_nm, two_approx
from repro.core.blocks import to_blocks
from repro.core.rounding import round_blocks

SIZES = [512, 1024, 2048]
N, M = 8, 16


def run():
    rng = np.random.default_rng(0)
    for size in SIZES:
        w = np.abs(rng.normal(size=(size, size))).astype(np.float32)
        blocks = to_blocks(jnp.asarray(w), M)
        nblk = blocks.shape[0]

        t = timeit(lambda b: block(solve_blocks(b, N, SolverConfig(iters=300))), blocks)
        emit(f"runtime_{size}_tsenor", t, f"blocks={nblk}")
        t = timeit(lambda b: block(dykstra_log(b, N, iters=300)), blocks)
        emit(f"runtime_{size}_dykstra", t, f"blocks={nblk}")
        s = dykstra_log(blocks, N, iters=300)
        t = timeit(lambda s, b: block(round_blocks(s, b, N, 10)), s, blocks)
        emit(f"runtime_{size}_rounding", t, f"blocks={nblk}")
        t = timeit(lambda b: block(two_approx(b, N)), blocks)
        emit(f"runtime_{size}_2approx", t, f"blocks={nblk}")
        t = timeit(lambda b: block(bi_nm(b, N)), blocks)
        emit(f"runtime_{size}_binm", t, f"blocks={nblk}")


if __name__ == "__main__":
    run()
