"""Chaos harness: mask serving under faults, measured end to end.

Boots a :class:`repro.service.net.MaskServer` behind a
:class:`~repro.service.net.ChaosProxy` and drives four failure scenarios
against the resilient :class:`MaskClient`:

* **flaky-network** — random connection kills, torn frames and latency
  spikes during a full workload; gate: zero requests lost, every mask
  bit-identical to a clean in-process solve.
* **kill-restart** — the server process dies with the queue in flight and
  a fresh one (empty queues, cold cache) comes up behind the same address;
  the client's retried wait reports unknown ids and re-submits.  Measures
  recovery latency (kill -> flush complete); gates zero lost +
  bit-identity.
* **degraded** — every endpoint stays down past the retry budget; the
  flush completes through the client's local in-process fallback.  Gate:
  bit-identical, ``stats.degraded`` set.
* **dst-refresh** — a :class:`MaskRefreshController` refreshing through
  the lossy proxy while connections are severed around the swap step;
  gate: nothing raises into the step loop and the final compressed params
  are bit-identical to an undisturbed run (failed refreshes only delay the
  swap — same weights, same masks).

All fault schedules are seeded (proxy RNG + retry jitter RNG), so a run is
reproducible fault-for-fault.  Writes ``BENCH_chaos.json``; ``--smoke``
shrinks the workload and turns the gates into hard asserts for CI.

Run:    PYTHONPATH=src:. python benchmarks/service_chaos.py
Smoke:  PYTHONPATH=src:. python benchmarks/service_chaos.py --smoke
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import numpy as np

from benchmarks.common import emit
from repro.api import MaskService, PatternSpec, SolverConfig
from repro.service import BucketPolicy
from repro.service.net import ChaosProxy, MaskClient, MaskServer, RetryPolicy

TINY = BucketPolicy(base=8, growth=2, max_bucket=64)


def workload(n_tensors: int, seed: int, max_side: int):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_tensors):
        r = int(rng.integers(1, max_side // 4 + 1)) * 4
        c = int(rng.integers(1, max_side // 4 + 1)) * 4
        out.append((f"w{i}", rng.normal(size=(r, c)).astype(np.float32)))
    return out


def make_server(solver, **kw):
    kw.setdefault("batch_window_s", 0.002)
    return MaskServer(MaskService(solver, policy=TINY), **kw).start()


def reference(solver, items):
    local = MaskService(solver, policy=TINY)
    return {n: np.asarray(local.solve(w, "t2:4")) for n, w in items}


def identical(handles, want) -> bool:
    return all(
        np.array_equal(np.asarray(h.result()), want[n])
        for n, h in handles.items()
    )


def scenario_flaky_network(solver, items, want, policy) -> dict:
    """Random kills + torn frames + latency during a whole workload."""
    srv = make_server(solver)
    try:
        with ChaosProxy(srv.address, seed=11, latency_s=0.001,
                        latency_jitter_s=0.002) as proxy:
            with MaskClient(proxy.address, tenant="flaky",
                            retry=policy) as c:
                proxy.kill_rate = 0.02   # armed after the hello
                proxy.torn_rate = 0.01
                t0 = time.monotonic()
                handles = {n: c.submit(n, w, "t2:4", journal=False)
                           for n, w in items}
                c.flush()
                makespan = time.monotonic() - t0
                lost = sum(1 for h in handles.values() if not h.done)
                ok = identical(handles, want)
                stats = c.stats
            return {
                "makespan_seconds": makespan,
                "requests_lost": lost,
                "bit_identical": ok,
                "client_retries": stats.retries,
                "client_resubmitted": stats.resubmitted,
                "degraded": stats.degraded,
                "proxy_connections": proxy.connections,
                "proxy_killed": proxy.killed,
                "proxy_torn": proxy.torn,
            }
    finally:
        srv.stop()


def scenario_kill_restart(solver, items, want, policy) -> dict:
    """Hard-kill the server mid-flight; restart it cold behind the proxy."""
    srv1 = make_server(solver, batch_window_s=0.5)  # linger: queue stays hot
    proxy = ChaosProxy(srv1.address, seed=12)
    srv2 = None
    try:
        with MaskClient(proxy.address, tenant="restart",
                        retry=policy) as c:
            handles = {n: c.submit(n, w, "t2:4", journal=False)
                       for n, w in items}
            t_kill = time.monotonic()
            srv1.stop()
            proxy.kill_connections()
            srv2 = make_server(solver)
            proxy.retarget((srv2.host, srv2.port))
            t_up = time.monotonic()
            c.flush()
            t_done = time.monotonic()
            return {
                "requests_inflight_at_kill": len(items),
                "requests_lost": sum(
                    1 for h in handles.values() if not h.done),
                "bit_identical": identical(handles, want),
                "recovery_seconds_from_kill": t_done - t_kill,
                "recovery_seconds_from_restart": t_done - t_up,
                "client_retries": c.stats.retries,
                "client_resubmitted": c.stats.resubmitted,
                "degraded": c.stats.degraded,
            }
    finally:
        proxy.stop()
        srv1.stop()
        if srv2 is not None:
            srv2.stop()


def scenario_degraded(solver, items, want) -> dict:
    """Server dies and never comes back: local fallback finishes the job."""
    srv = make_server(solver, batch_window_s=0.5)
    policy = RetryPolicy(max_attempts=3, base_s=0.01, cap_s=0.05,
                         deadline_s=10.0, seed=0)
    c = MaskClient(srv.address, tenant="degraded", retry=policy)
    try:
        handles = {n: c.submit(n, w, "t2:4", journal=False)
                   for n, w in items}
        srv.stop()
        t0 = time.monotonic()
        c.flush()
        return {
            "fallback_seconds": time.monotonic() - t0,
            "requests_lost": sum(1 for h in handles.values() if not h.done),
            "bit_identical": identical(handles, want),
            "degraded": c.stats.degraded,
            "client_retries": c.stats.retries,
        }
    finally:
        c.close()
        srv.stop()


def scenario_dst_refresh(solver, policy, steps: int) -> dict:
    """A DST refresh riding the lossy wire: severed connections around the
    swap step delay the refresh (failed event + re-arm) but never change
    the masks or crash the loop."""
    import jax
    import jax.numpy as jnp

    from repro.dst import MaskRefreshController, StepwiseSchedule
    from repro.models import lm
    from repro.models.config import ModelConfig
    from repro.optim import AdamW
    from repro.sparsity.masks import apply_mask, sparsify_pytree
    from repro.sparsity.params import compress_params, projection_prunable
    from repro.train import make_train_state

    cfg = ModelConfig("chaos-dst", "dense", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                      remat="none", dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    pattern = PatternSpec(24, 32)
    masks = sparsify_pytree(params, pattern, config=solver,
                            prunable=projection_prunable)
    sp = compress_params(apply_mask(params, masks), masks, pattern)
    opt = AdamW(learning_rate=1e-3, clip_norm=0.0)

    def fresh_state():
        return make_train_state(cfg, opt, jax.random.PRNGKey(1), params=sp,
                                compression=False)

    sched = StepwiseSchedule(((0, "t24:32"), (3, "t16:32")))

    def drive(service, chaos=None):
        ctrl = MaskRefreshController(sched, service=service, mode="async",
                                     lookahead=2)
        state = fresh_state()
        for t in range(steps):
            if chaos is not None and t in (2, 3):
                chaos()  # sever everything right around the swap
            state = ctrl.on_step(t, state._replace(
                step=jnp.asarray(t, jnp.int32)))
        return ctrl, state

    # Undisturbed oracle (local in-process service).
    _, state_ref = drive(MaskService(solver, policy=TINY))

    srv = make_server(solver)
    try:
        with ChaosProxy(srv.address, seed=13, latency_s=0.001) as proxy:
            with MaskClient(proxy.address, tenant="dst",
                            retry=policy) as c:
                ctrl, state_chaos = drive(c, chaos=proxy.kill_connections)
                refreshed = any(not e.failed for e in ctrl.events)
                failed = sum(1 for e in ctrl.events if e.failed)
                degraded = c.stats.degraded
    finally:
        srv.stop()

    import jax as _jax
    leaves_a = _jax.tree.leaves(state_chaos.params)
    leaves_b = _jax.tree.leaves(state_ref.params)
    same = len(leaves_a) == len(leaves_b) and all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(leaves_a, leaves_b)
    )
    return {
        "steps": steps,
        "refresh_landed": refreshed,
        "failed_refreshes": failed,
        "params_bit_identical": same,
        "degraded": degraded,
    }


def run(args) -> dict:
    solver = SolverConfig(iters=40 if args.smoke else 100)
    n_tensors = 6 if args.smoke else 40
    max_side = 24 if args.smoke else 64
    steps = 10 if args.smoke else 16
    policy = RetryPolicy(max_attempts=12, base_s=0.02, cap_s=0.25,
                         deadline_s=120.0, seed=0)

    items = workload(n_tensors, seed=1, max_side=max_side)
    want = reference(solver, items)

    scenarios = {
        "flaky_network": scenario_flaky_network(solver, items, want, policy),
        "kill_restart": scenario_kill_restart(solver, items, want, policy),
        "degraded": scenario_degraded(solver, items, want),
        "dst_refresh": scenario_dst_refresh(solver, policy, steps),
    }

    lost = sum(s.get("requests_lost", 0) for s in scenarios.values())
    all_identical = all(
        s.get("bit_identical", s.get("params_bit_identical", True))
        for s in scenarios.values()
    )
    # emit() prints microseconds; the lost-request count is a plain CSV row.
    print(f"chaos_requests_lost,{lost},"
          f"across {len(scenarios)} scenarios (gate: 0)")
    emit("chaos_recovery",
         scenarios["kill_restart"]["recovery_seconds_from_kill"],
         "server kill -> flush complete")
    emit("chaos_degraded_fallback",
         scenarios["degraded"]["fallback_seconds"],
         "all endpoints down -> local solve complete")

    doc = {
        "meta": {
            "benchmark": "service_chaos",
            "platform": platform.platform(),
            "python": platform.python_version(),
            "smoke": args.smoke,
            "solver_iters": solver.iters,
            "tensors": n_tensors,
            "retry_policy": {
                "max_attempts": policy.max_attempts,
                "base_s": policy.base_s,
                "cap_s": policy.cap_s,
                "deadline_s": policy.deadline_s,
            },
        },
        "headline": {
            "requests_lost_total": lost,
            "bit_identical_everywhere": all_identical,
            "recovery_seconds_from_kill":
                scenarios["kill_restart"]["recovery_seconds_from_kill"],
            "degraded_fallback_seconds":
                scenarios["degraded"]["fallback_seconds"],
            "dst_refresh_landed": scenarios["dst_refresh"]["refresh_landed"],
        },
        "scenarios": scenarios,
    }

    if args.smoke:
        # The issue's acceptance gates, as hard asserts for CI.
        assert lost == 0, f"requests lost under chaos: {scenarios}"
        assert all_identical, f"masks diverged under chaos: {scenarios}"
        assert not scenarios["flaky_network"]["degraded"], (
            "flaky network should recover over the wire, not degrade")
        assert scenarios["kill_restart"]["client_resubmitted"] > 0, (
            "restart scenario never exercised re-submission")
        assert scenarios["degraded"]["degraded"], (
            "degraded scenario never entered the fallback")
        assert scenarios["dst_refresh"]["refresh_landed"], (
            "DST refresh never landed under chaos")
        print("SMOKE OK: zero lost, bit-identical under chaos, "
              "degraded fallback engaged")
    return doc


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small workload + hard CI gates")
    ap.add_argument("--out", default="BENCH_chaos.json")
    args = ap.parse_args()
    doc = run(args)
    doc["meta"]["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                             time.gmtime())
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
