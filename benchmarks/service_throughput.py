"""Mask-service throughput: bucketed mega-batches vs the naive per-tensor loop.

Workload: a transformer-like mix of layer shapes (projections of several
widths, stacked QKV tensors, odd-shaped heads needing padding) — exactly the
long-tail mix where the per-tensor path drowns in one XLA compilation per
distinct block count plus one dispatch per tensor.  Both paths run the SAME
jitted solver program; only the dispatch strategy differs, so blocks/sec
isolates the scheduling win.

Timings are end-to-end for a fresh workload (compilations included — mask
generation is a one-shot pipeline, so compile time IS wall-clock the user
pays), with a second warm pass reported for the steady-state comparison.
Both paths use the unified API: the service side is the canonical
``MaskService.solve`` machinery (submit + flush), the naive side the
per-tensor ``solve_mask``.

    PYTHONPATH=src python benchmarks/service_throughput.py [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.api import BucketPolicy, MaskService, PatternSpec, SolverConfig, solve_mask
from repro.service.scheduler import tensor_to_blocks

N, M = 4, 8
PATTERN = PatternSpec(N, M)


def workload(smoke: bool = False):
    """(name, array) pairs over a mixed-shape, many-small-layers model."""
    rng = np.random.default_rng(0)
    if smoke:
        widths, layers, stack = [32, 48, 64], 2, 2
    else:
        widths, layers, stack = [64, 96, 128, 160, 192, 256, 72, 120], 4, 6
    tensors = []
    for l in range(layers):
        for d in widths:
            tensors.append((f"l{l}/proj_{d}", rng.normal(size=(d, d))))
            tensors.append((f"l{l}/up_{d}", rng.normal(size=(d, 2 * d))))
        tensors.append((f"l{l}/odd", rng.normal(size=(widths[l % len(widths)] + 4,
                                                      widths[0] - 4))))
    tensors.append(("qkv_stack", rng.normal(size=(stack, widths[0], widths[0]))))
    return [(name, w.astype(np.float32)) for name, w in tensors]


def count_blocks(tensors) -> int:
    return sum(tensor_to_blocks(w, M)[0].shape[0] for _, w in tensors)


def naive_pass(tensors, config) -> float:
    t0 = time.perf_counter()
    outs = []
    for _, w in tensors:
        if w.ndim == 3:  # per-tensor path loops the stacked layers too
            outs.extend(
                solve_mask(jnp.asarray(w[i]), PATTERN, config)
                for i in range(w.shape[0])
            )
        else:
            outs.append(solve_mask(jnp.asarray(w), PATTERN, config))
    for o in outs:
        o.block_until_ready()
    return time.perf_counter() - t0


def service_pass(tensors, config, policy) -> tuple[float, MaskService]:
    t0 = time.perf_counter()
    svc = MaskService(config, policy=policy)
    handles = [svc.submit(name, w, PATTERN) for name, w in tensors]
    svc.flush()
    for h in handles:
        h.result()
    return time.perf_counter() - t0, svc


def run(smoke: bool = False):
    config = SolverConfig(iters=40 if smoke else 80)
    policy = BucketPolicy(base=64, growth=4, max_bucket=4096)
    tensors = workload(smoke)
    blocks = count_blocks(tensors)

    # Cold = compilations included; warm = steady-state dispatch + compute.
    # The two paths hit disjoint jit shapes (per-tensor block counts vs
    # bucket sizes), so in-process ordering doesn't cross-contaminate.
    svc_cold, svc = service_pass(tensors, config, policy)
    svc_warm, _ = service_pass(tensors, config, policy)
    naive_cold = naive_pass(tensors, config)
    naive_warm = naive_pass(tensors, config)

    speedup = naive_cold / svc_cold
    emit("service_throughput_naive_cold", naive_cold, f"bps={blocks / naive_cold:.0f}")
    emit("service_throughput_service_cold", svc_cold,
         f"bps={blocks / svc_cold:.0f},speedup={speedup:.2f}x,"
         f"tensors={len(tensors)},batches={svc.stats.batches}")
    emit("service_throughput_naive_warm", naive_warm, f"bps={blocks / naive_warm:.0f}")
    emit("service_throughput_service_warm", svc_warm,
         f"bps={blocks / svc_warm:.0f},speedup={naive_warm / svc_warm:.2f}x")
    print(f"# {len(tensors)} tensors, {blocks} blocks: "
          f"service {blocks / svc_cold:.0f} blocks/s vs naive "
          f"{blocks / naive_cold:.0f} blocks/s -> {speedup:.1f}x (cold), "
          f"{naive_warm / svc_warm:.1f}x (warm)")
    return speedup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for CI; asserts service >= naive")
    args = ap.parse_args()
    speedup = run(smoke=args.smoke)
    if args.smoke:
        assert speedup >= 1.0, f"service slower than naive loop: {speedup:.2f}x"


if __name__ == "__main__":
    main()
