"""Dynamic sparse training loop: async-refresh overhead, stalls, and quality.

Measures the three numbers the ``repro.dst`` design hinges on and writes a
machine-readable ``BENCH_dst.json``:

* **overhead** — median seconds/step of a compressed training loop whose
  masks are being re-solved asynchronously (a ``StaticSchedule`` refresh:
  same pattern, so the per-step compute is *identical* to the no-refresh
  baseline and the delta is purely the DST machinery).  Swap steps are
  excluded from the median — a swap recompresses host-side and re-traces,
  a once-per-refresh cost reported separately (``swap_overhead_seconds``).
  The ``--smoke`` gate holds the overhead under 5%.
* **stalls** — trainer time spent blocked on an in-flight flush at swap
  steps (``MaskRefreshController.stall_seconds``).  With enough lookahead
  the background solve finishes before the swap lands, so the gate holds
  total stall under 10% of ONE baseline step: *zero trainer stalls
  attributable to the flush*, up to timer noise.  The solver/flush path is
  warmed before timing — jit compilation is a process-lifetime cost, not a
  per-refresh one, and on this 1-CPU container an unwarmed background
  flush would bill its compile to the trainer.
* **quality** — a Kao-style decaying-N:M run (24:32 → 20:32 → 16:32 on a
  :func:`repro.dst.schedule.decaying_nm` schedule) vs a one-shot 16:32
  prune-then-train run over the *same* pretrained weights, step budget,
  data, and seeds.  "Final loss" is the mean over the last 4 steps (one
  batch's loss is noise).  The decayed run must end no worse (``--smoke``
  asserts ``dst <= oneshot * 1.005``); held-out eval losses are reported
  alongside.

Per-refresh flip telemetry (kept/added/dropped, flip rate per swap) rides
the events section verbatim — the number Kao et al. watch to keep
late-stage churn down.

On this CPU container the absolute step times measure the interpret-mode
kernel dispatch, not TPU bandwidth; the *ratios* (overhead, stall fraction)
are the portable numbers.

Run:    PYTHONPATH=src:. python benchmarks/dst_loop.py
Smoke:  PYTHONPATH=src:. python benchmarks/dst_loop.py --smoke
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.api import PatternSpec, SolverConfig
from repro.data import SyntheticLM
from repro.dst import MaskRefreshController, StaticSchedule, decaying_nm
from repro.kernels import default_interpret
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import AdamW
from repro.service import MaskService
from repro.sparsity.masks import apply_mask, sparsify_pytree
from repro.sparsity.params import (
    NMCompressed,
    compress_params,
    projection_prunable,
)
from repro.train import build_train_step, make_train_state
from repro.train.step import StepConfig

SMOKE_CFG = ModelConfig("dst-smoke", "dense", num_layers=2, d_model=64,
                        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                        remat="none", dtype="float32")
FULL_CFG = ModelConfig("dst-30m", "dense", num_layers=6, d_model=384,
                       num_heads=6, num_kv_heads=2, d_ff=1536, vocab_size=8192,
                       remat="none", dtype="float32")


def _pretrain(cfg, data, steps):
    """Brief shared dense pretrain: masks from trained weights are the
    workload both quality arms share (pruning random init compares noise)."""
    opt = AdamW(learning_rate=1e-3, clip_norm=0.0)
    state = make_train_state(cfg, opt, jax.random.PRNGKey(1))
    step = build_train_step(cfg, opt, donate=False)
    for t in range(steps):
        state, _ = step(state, {k: jnp.asarray(v)
                                for k, v in data.batch(t).items()})
    return state.params


def _compressed_state(cfg, dense_params, spec, solver_iters):
    masks = sparsify_pytree(dense_params, spec,
                            config=SolverConfig(iters=solver_iters),
                            prunable=projection_prunable)
    sp = compress_params(apply_mask(dense_params, masks), masks, spec)
    opt = AdamW(learning_rate=1e-3, clip_norm=0.0)
    return opt, make_train_state(cfg, opt, jax.random.PRNGKey(2), params=sp)


def _warm_flush_path(state, spec, solver_iters):
    """Compile the service's bucketed solve + bit-pack paths for every
    compressed leaf shape, on a throwaway service (the jit cache is
    process-global; the content cache is not shared, so the timed
    controllers still solve for real)."""
    svc = MaskService(SolverConfig(iters=solver_iters))
    for i, leaf in enumerate(jax.tree.leaves(
            state.params, is_leaf=lambda x: isinstance(x, NMCompressed))):
        if isinstance(leaf, NMCompressed):
            svc.submit(f"warm{i}", leaf.decompress(), spec, journal=False)
    svc.flush()


def _run_loop(cfg, opt, state, batches, refresh=None):
    """Train over ``batches``; returns (state, per-step sec, losses, swaps)."""
    step = build_train_step(
        cfg, opt,
        step_cfg=StepConfig(mask_mode="compressed", refresh=refresh),
        donate=False)
    times, losses, swaps = [], [], []
    for t, b in enumerate(batches):
        n_events = len(refresh.events) if refresh is not None else 0
        t0 = time.perf_counter()
        state, metrics = step(state, b)
        jax.block_until_ready(metrics["loss"])
        times.append(time.perf_counter() - t0)
        losses.append(float(np.asarray(metrics["loss"])))
        if refresh is not None and len(refresh.events) > n_events:
            swaps.append(t)
    return state, times, losses, swaps


def _eval_loss(cfg, params, data, reps=4):
    return float(np.mean([
        float(lm.loss_fn(params, cfg, {k: jnp.asarray(v) for k, v in
                                       data.batch(90_000 + i).items()}))
        for i in range(reps)
    ]))


def _median_excluding(times, exclude):
    kept = [s for t, s in enumerate(times) if t not in set(exclude)]
    return float(np.median(kept if kept else times))


def run(cfg: ModelConfig, seq: int, batch: int, steps: int, every: int,
        lookahead: int, pretrain: int, decay_window: int, solver_iters: int,
        out_path: str) -> dict:
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq,
                       global_batch=batch)
    dense_params = _pretrain(cfg, data, pretrain)
    batches = [{k: jnp.asarray(v) for k, v in data.batch(pretrain + t).items()}
               for t in range(steps)]

    # -- overhead: static-pattern refresh vs no refresh (identical compute) --
    target = PatternSpec(16, 32)
    opt, state = _compressed_state(cfg, dense_params, target, solver_iters)
    _warm_flush_path(state, target, solver_iters)
    _, base_times, _, _ = _run_loop(cfg, opt, state, batches)
    base_med = _median_excluding(base_times, [0])  # drop the compile step

    sched = StaticSchedule(target, every=every)
    ctrl = MaskRefreshController(sched, solver=SolverConfig(iters=solver_iters),
                                 mode="async", lookahead=lookahead)
    opt, state = _compressed_state(cfg, dense_params, target, solver_iters)
    _, dst_times, _, swaps = _run_loop(cfg, opt, state, batches, refresh=ctrl)
    dst_med = _median_excluding(dst_times, [0] + swaps)
    overhead = dst_med / base_med - 1.0
    swap_cost = float(sum(dst_times[t] for t in swaps) - base_med * len(swaps))
    emit("dst_step_overhead", dst_med,
         f"base={base_med * 1e3:.1f}ms overhead={overhead * 100:+.1f}% "
         f"stall={ctrl.stall_seconds() * 1e3:.1f}ms "
         f"refreshes={len(ctrl.events)}")

    # -- quality: decaying N:M vs one-shot, same weights/budget/data/seeds ---
    # Shorter lookahead than the overhead arm: quality pays for mask
    # staleness, and the tiny smoke solves finish well within 2 steps.
    decay = decaying_nm(32, 24, 16, total_steps=decay_window, stages=3)
    qctrl = MaskRefreshController(decay, solver=SolverConfig(iters=solver_iters),
                                  mode="async",
                                  lookahead=max(1, lookahead // 2))
    opt, dstate = _compressed_state(cfg, dense_params, decay.initial,
                                    solver_iters)
    dstate, _, dst_losses, _ = _run_loop(cfg, opt, dstate, batches,
                                         refresh=qctrl)
    dst_final = float(np.mean(dst_losses[-4:]))
    dst_eval = _eval_loss(cfg, dstate.params, data)

    opt, ostate = _compressed_state(cfg, dense_params, target, solver_iters)
    ostate, _, one_losses, _ = _run_loop(cfg, opt, ostate, batches)
    one_final = float(np.mean(one_losses[-4:]))
    one_eval = _eval_loss(cfg, ostate.params, data)
    emit("dst_decaying_quality", dst_final,
         f"oneshot={one_final:.4f} delta={dst_final - one_final:+.4f} "
         f"(eval {dst_eval:.4f} vs {one_eval:.4f})")

    doc = {
        "meta": {
            "benchmark": "dst_loop",
            "platform": platform.platform(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "device": str(jax.local_devices()[0].device_kind),
            "interpret_mode": default_interpret(),
            "model": cfg.name,
            "seq_len": seq, "batch": batch, "steps": steps,
            "pretrain_steps": pretrain, "decay_window": decay_window,
            "refresh_every": every, "lookahead": lookahead,
        },
        "headline": {
            "step_overhead_frac": overhead,
            "stall_seconds": ctrl.stall_seconds(),
            "stall_frac_of_step": ctrl.stall_seconds() / base_med,
            "refreshes": len(ctrl.events),
            "dst_final_loss": dst_final,
            "oneshot_final_loss": one_final,
            "quality_delta": dst_final - one_final,
            "dst_eval_loss": dst_eval,
            "oneshot_eval_loss": one_eval,
        },
        "overhead": {
            "baseline_median_sec": base_med,
            "dst_median_sec": dst_med,
            "swap_steps": swaps,
            "swap_overhead_seconds": swap_cost,
            "per_step_sec": {"baseline": base_times, "dst": dst_times},
        },
        "quality": {
            "schedule": decay.spec(),
            "dst_losses": dst_losses,
            "oneshot_losses": one_losses,
            "dst_refreshes": [e.to_json() for e in qctrl.events],
        },
        "telemetry": ctrl.telemetry(),
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"wrote {out_path}")
    for e in qctrl.events:
        print(f"  {e.summary()}")
    return doc


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model / few steps (CI regression gate)")
    ap.add_argument("--out", default="BENCH_dst.json")
    args = ap.parse_args()
    if args.smoke:
        doc = run(SMOKE_CFG, seq=32, batch=4, steps=40, every=12, lookahead=4,
                  pretrain=12, decay_window=12, solver_iters=30,
                  out_path=args.out)
        head = doc["headline"]
        # Gate 1: async refresh adds <5% to the median step.
        assert head["step_overhead_frac"] < 0.05, head
        # Gate 2: no stalls attributable to the flush — total wait across
        # every swap stays under 10% of ONE step (timer noise headroom).
        assert head["stall_frac_of_step"] < 0.1, head
        # Gate 3: decaying-N:M ends no worse than one-shot at equal budget
        # (0.5% headroom over bit-determinism for platform jitter).
        assert head["dst_final_loss"] <= head["oneshot_final_loss"] * 1.005, \
            head
    else:
        run(FULL_CFG, seq=64, batch=8, steps=36, every=12, lookahead=6,
            pretrain=8, decay_window=18, solver_iters=60, out_path=args.out)


if __name__ == "__main__":
    main()
