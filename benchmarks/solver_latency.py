"""Solver backend latency: dense-jit vs pallas vs pallas-fused.

Times the registered solver backends over (B, M, M) block batches across
M in {4, 8, 16, 32}, including the ``pallas-fused`` single-pass kernel at
several early-exit tolerances, and writes a machine-readable
``BENCH_solver.json`` with:

* ``blocks_per_sec`` — median wall-clock throughput per backend config;
* ``hbm_bytes_model`` — analytic bytes-moved model (see ``_bytes_model``):
  the split pipelines pay ~5 HBM round-trips of the M² plan/order tensors,
  the fused kernel one |W| read plus one bit-packed (M bits/row) mask write;
* ``objective_ratio`` — mask objective vs the full-T dense-jit reference
  (1.0 means identical or equal-quality masks);
* ``iters_histogram`` — per-tile Dykstra iteration counts of the adaptive
  early-exit rows ({iterations: tile count});
* a ``headline`` block with the M=32 fused-vs-pallas speedup the ROADMAP
  tracks.

Run:    PYTHONPATH=src:. python benchmarks/solver_latency.py
Smoke:  PYTHONPATH=src:. python benchmarks/solver_latency.py --smoke
        (tiny shapes, few iterations — the CI kernel-regression gate)
"""
from __future__ import annotations

import argparse
import json
import platform
import time
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import block, emit
from repro.core import SolverConfig, get_backend
from repro.kernels import default_interpret
from repro.kernels.fused_solve import fused_block_b, fused_solve
from repro.patterns import PatternSpec

# (M, batch) per row; N = M/2 (the transposable patterns the paper evaluates).
FULL_CASES = [(4, 8192), (8, 4096), (16, 2048), (32, 2048)]
SMOKE_CASES = [(4, 64), (8, 64)]

# Fused-backend early-exit tolerances benchmarked alongside tol=0.
TOLERANCES = [1e-4, 3e-2, 5e-2, 7.5e-2]


def _timeit(fn, *args, reps: int) -> float:
    block(fn(*args))  # warmup + compile; block so rep 1 starts clean
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        block(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _bytes_model(backend: str, b: int, m: int) -> int:
    """Analytic HBM bytes per solve of a (B, M, M) float32 batch.

    Split pipelines (dense-jit / pallas) stream the M² tensors through HBM
    between stages: |W| read, fractional plan write+read, argsort order
    write+read (int32), bool mask write, plus the local-search re-read of
    |W| and mask.  The fused kernel reads |W| once and writes M bits per
    mask row (uint32 words) — the plan, order and counters stay in VMEM.
    """
    mm = b * m * m
    if backend == "pallas-fused":
        return 4 * mm + 4 * b * m  # |W| in, packed words out
    # w read + plan out/in + order out/in + mask out + LS pass (w + mask).
    return 4 * mm * 5 + 1 * mm + 4 * mm + 1 * mm


def _objective(mask: np.ndarray, w: np.ndarray) -> float:
    return float(np.sum(np.where(mask, w, 0.0), dtype=np.float64))


def run(cases, iters: int, reps: int, out_path: str) -> dict:
    rng = np.random.default_rng(0)
    results = []
    headline = {}
    for m, batch in cases:
        n = m // 2
        spec = PatternSpec(n, m)
        w = np.abs(rng.normal(size=(batch, m, m))).astype(np.float32)
        wj = jnp.asarray(w)

        # Full-T dense-jit reference mask for quality ratios.
        ref_config = SolverConfig(iters=iters)
        ref_mask = np.array(get_backend("dense-jit").solve(wj, spec, ref_config))
        ref_obj = _objective(ref_mask, w)

        per_backend_bps = {}
        for backend, tol in (
            [("dense-jit", 0.0), ("pallas", 0.0), ("pallas-fused", 0.0)]
            + [("pallas-fused", t) for t in TOLERANCES]
        ):
            config = SolverConfig(iters=iters, backend=backend, tol=tol)
            be = get_backend(backend)
            seconds = _timeit(lambda x: be.solve(x, spec, config), wj, reps=reps)
            if backend == "pallas-fused":
                # One solve yields mask, objective AND the iteration counts.
                from repro.sparsity.bitpack import unpack_rows_np

                words, tile_iters = fused_solve(wj, n, iters=iters, tol=tol)
                mask = unpack_rows_np(np.array(words), m)
            else:
                mask = np.array(be.solve(wj, spec, config))
            row = {
                "m": m,
                "n": n,
                "batch": batch,
                "backend": backend,
                "tol": tol,
                "iters": iters,
                "seconds_median": seconds,
                "blocks_per_sec": batch / seconds,
                "hbm_bytes_model": _bytes_model(backend, batch, m),
                "objective_ratio": _objective(mask, w) / ref_obj,
            }
            if backend == "pallas-fused" and tol > 0.0:
                row["iters_histogram"] = {
                    str(k): v for k, v in
                    sorted(Counter(np.array(tile_iters).tolist()).items())
                }
                row["tile_blocks"] = fused_block_b(m)
            if tol == 0.0:
                per_backend_bps[backend] = row["blocks_per_sec"]
            results.append(row)
            emit(
                f"latency_m{m}_b{batch}_{backend}"
                + (f"_tol{tol:g}" if tol else ""),
                seconds,
                f"blocks/s={row['blocks_per_sec']:.0f}"
                f" obj={row['objective_ratio']:.5f}",
            )

        fused_rows = [
            r for r in results
            if r["m"] == m and r["backend"] == "pallas-fused"
        ]
        best = max(fused_rows, key=lambda r: r["blocks_per_sec"])
        summary = {
            "fused_best_tol": best["tol"],
            "fused_best_blocks_per_sec": best["blocks_per_sec"],
            "fused_best_objective_ratio": best["objective_ratio"],
            "speedup_vs_pallas": best["blocks_per_sec"]
            / per_backend_bps["pallas"],
            "speedup_vs_dense_jit": best["blocks_per_sec"]
            / per_backend_bps["dense-jit"],
        }
        headline[f"m{m}"] = summary
        emit(
            f"headline_m{m}", 0.0,
            f"fused(tol={best['tol']:g}) = {summary['speedup_vs_pallas']:.2f}x"
            f" pallas, obj={best['objective_ratio']:.5f}",
        )

    doc = {
        "meta": {
            "benchmark": "solver_latency",
            "platform": platform.platform(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "device": str(jax.local_devices()[0].device_kind),
            "interpret_mode": default_interpret(),
            "iters": iters,
            "reps": reps,
            "ls_steps": 10,
        },
        "headline": headline,
        "results": results,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=False)
        f.write("\n")
    print(f"wrote {out_path}")
    return doc


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few iters (CI regression gate)")
    ap.add_argument("--out", default="BENCH_solver.json")
    ap.add_argument("--reps", type=int, default=None)
    args = ap.parse_args()
    if args.smoke:
        doc = run(SMOKE_CASES, iters=60, reps=args.reps or 1,
                  out_path=args.out)
        # The smoke gate fails CI when the fused kernel regresses: at tol=0
        # its masks must match dense-jit exactly (objective ratio 1.0), and
        # the adaptive rows must stay near-optimal.
        for r in doc["results"]:
            if r["backend"] == "pallas-fused":
                if r["tol"] == 0.0:
                    assert r["objective_ratio"] == 1.0, r
                else:
                    assert r["objective_ratio"] >= 0.99, r
    else:
        run(FULL_CASES, iters=300, reps=args.reps or 5, out_path=args.out)


if __name__ == "__main__":
    main()
