"""Paper Tab. 2 (miniature): end-to-end one-shot pruning of a small trained
LM with Wanda / SparseGPT / ALPS under transposable N:M, evaluated by LM loss.

Uses the sequential layer-wise runner (pruned activations propagate to later
layers, as in the paper's LLaMA pipeline).  Validates the paper's *orderings*
(absolute perplexities need the real corpora): ALPS <= SparseGPT <= Wanda
under transposable masks, and larger M hurts less.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.api import PatternSpec, SolverConfig
from repro.data import SyntheticLM
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import AdamW, warmup_cosine
from repro.pruning import prune_transformer
from repro.train import TrainLoop, TrainLoopConfig, build_train_step, make_train_state

CFG = ModelConfig("bench-lm", "dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=128, remat="none",
                  dtype="float32")
FAST = SolverConfig(iters=100)


def pretrain(steps=150):
    data = SyntheticLM(vocab_size=CFG.vocab_size, seq_len=32, global_batch=8)
    opt = AdamW(learning_rate=warmup_cosine(5e-3, 10, steps))
    state = make_train_state(CFG, opt, jax.random.PRNGKey(0))
    loop = TrainLoop(build_train_step(CFG, opt, donate=False), data, None,
                     TrainLoopConfig(total_steps=steps, log_every=10**9),
                     log_fn=lambda s: None)
    state, _ = loop.run(state)
    return state.params, data


def eval_loss(params, data, steps=4):
    return float(np.mean([
        float(lm.loss_fn(params, CFG, {k: jnp.asarray(v) for k, v in
                                       data.batch(50_000 + i).items()}))
        for i in range(steps)
    ]))


def run():
    params, data = pretrain()
    dense = eval_loss(params, data)
    emit("prune_dense_loss", 0.0, f"loss={dense:.4f}")
    calib = jnp.asarray(data.batch(0)["tokens"])
    results = {}
    for n, m in [(2, 4), (8, 16)]:
        for method in ("wanda", "sparsegpt", "alps"):
            pruned, _ = prune_transformer(
                params, CFG, tokens=calib, method=method,
                pattern=PatternSpec(n, m, True), solver=FAST,
            )
            loss = eval_loss(pruned, data)
            results[(method, m)] = loss
            emit(f"prune_{n}:{m}_{method}_tran", 0.0, f"loss={loss:.4f}")
    for m in (4, 16):
        ok = results[("alps", m)] <= results[("sparsegpt", m)] + 0.05
        emit(f"prune_ordering_alps_le_sparsegpt_m{m}", 0.0, f"ok={ok}")
    # larger M hurts less (for the strongest method)
    emit("prune_larger_m_better", 0.0,
         f"ok={results[('alps', 16)] <= results[('alps', 4)] + 0.02}")


if __name__ == "__main__":
    run()
