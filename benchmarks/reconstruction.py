"""Paper Tab. 4: layer-wise reconstruction error, standard vs transposable
N:M across patterns at 50% and 75% sparsity (ALPS, correlated activations).

Claims validated: transposable error >= standard; the gap shrinks as M grows;
transposable 8:16 beats standard 2:4 (large-M transposable > small-M standard).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.api import PatternSpec, SolverConfig
from repro.pruning import alps_prune, gram_matrix, reconstruction_error
from repro.pruning.alps import AlpsConfig

PATTERNS_50 = [(2, 4), (4, 8), (8, 16)]
PATTERNS_75 = [(1, 4), (2, 8), (4, 16)]


def run():
    rng = np.random.default_rng(2)
    t, din, dout = 512, 128, 96
    x = (rng.normal(size=(t, 16)) @ rng.normal(size=(16, din))
         + 0.3 * rng.normal(size=(t, din))).astype(np.float32)
    w = rng.normal(size=(din, dout)).astype(np.float32)
    xj, wj = jnp.asarray(x), jnp.asarray(w)
    h = gram_matrix(xj)
    cfg = AlpsConfig(iters=60, solver=SolverConfig(iters=100))

    for patterns, tag in ((PATTERNS_50, "50pct"), (PATTERNS_75, "75pct")):
        for n, m in patterns:
            for transposable in (False, True):
                wp, _ = alps_prune(wj, h, PatternSpec(n, m, transposable), config=cfg)
                e = float(reconstruction_error(xj, wj, wp))
                kind = "tran" if transposable else "std"
                emit(f"recon_{tag}_{n}:{m}_{kind}", 0.0, f"err={e:.5f}")


if __name__ == "__main__":
    run()
