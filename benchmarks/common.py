"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time

import jax
import numpy as np


def timeit(fn, *args, warmup: int = 1, reps: int = 3) -> float:
    """Median wall seconds (fn must block, e.g. via block_until_ready)."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def block(x):
    return jax.block_until_ready(x)


def emit(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}")
