"""Serve a (pruned) model with batched prefill + decode.

    PYTHONPATH=src python examples/serve_sparse.py [--arch granite_8b]

Instantiates an assigned architecture's smoke config, prunes it to
transposable N:M, and runs the batched serving engine (greedy decode with a
ring-buffer KV cache for SWA archs, SSM state for mamba archs).
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.api import PatternSpec, SolverConfig
from repro.models import lm
from repro.serve import ServeEngine
from repro.sparsity.masks import apply_mask, sparsify_pytree


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_8b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--n", type=int, default=2)
    ap.add_argument("--m", type=int, default=4)
    ap.add_argument("--dense", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    print(f"== serving {cfg.name} ({cfg.family}) ==")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    if not args.dense:
        masks = sparsify_pytree(params, PatternSpec(args.n, args.m),
                                config=SolverConfig(iters=100))
        params = apply_mask(params, masks)
        print(f"pruned to transposable {args.n}:{args.m}")

    eng = ServeEngine(cfg, params, max_len=args.prompt_len + args.new_tokens)
    if cfg.frontend != "none":
        embeds = jax.random.normal(
            jax.random.PRNGKey(1),
            (args.batch, args.prompt_len, cfg.d_model), jnp.float32) * 0.02
        out = eng.generate(None, args.new_tokens, embeds=embeds)
    else:
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
            cfg.vocab_size)
        out = eng.generate(prompts, args.new_tokens)
    print(f"generated {out.shape} tokens:")
    for row in list(out[:4]):
        print("  ", list(map(int, row)))


if __name__ == "__main__":
    main()
