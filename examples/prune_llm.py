"""One-shot transposable N:M pruning of an LM (paper Sec. 4/5 pipeline).

    PYTHONPATH=src python examples/prune_llm.py --method alps --n 8 --m 16

Pretrains a small llama-style model on the synthetic corpus (or loads a
checkpoint), runs the sequential layer-wise pruning runner (Wanda /
SparseGPT / ALPS + TSENOR), and reports loss before/after + mask validity.
Use ``--arch`` to prune any assigned architecture's *smoke* config.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.api import PatternSpec, SolverConfig, is_transposable_nm
from repro.data import SyntheticLM
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import AdamW, warmup_cosine
from repro.pruning import prune_transformer
from repro.train import TrainLoop, TrainLoopConfig, build_train_step, make_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="alps",
                    choices=["alps", "sparsegpt", "wanda", "magnitude"])
    ap.add_argument("--n", type=int, default=2)
    ap.add_argument("--m", type=int, default=4)
    ap.add_argument("--arch", default=None, help="smoke config of an assigned arch")
    ap.add_argument("--pretrain-steps", type=int, default=200)
    ap.add_argument("--standard", action="store_true",
                    help="standard (non-transposable) N:M")
    ap.add_argument("--journal-dir", default=None,
                    help="persist pruned tensors + journal here; re-running "
                         "after a kill resumes mid-model")
    args = ap.parse_args()

    if args.arch:
        cfg = get_smoke_config(args.arch)
        assert cfg.family in ("dense", "vlm", "audio"), \
            "runner covers attention+MLP families; use per-matrix APIs for MoE/SSM"
    else:
        cfg = ModelConfig("prune-demo", "dense", num_layers=4, d_model=128,
                          num_heads=8, num_kv_heads=4, d_ff=256, vocab_size=256,
                          remat="none", dtype="float32")
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)

    print(f"== pretraining {cfg.name} for {args.pretrain_steps} steps ==")
    opt = AdamW(learning_rate=warmup_cosine(3e-3, 20, args.pretrain_steps))
    state = make_train_state(cfg, opt, jax.random.PRNGKey(0))
    loop = TrainLoop(build_train_step(cfg, opt, donate=False), data, None,
                     TrainLoopConfig(total_steps=args.pretrain_steps, log_every=50))
    state, _ = loop.run(state)

    def eval_loss(params):
        return float(np.mean([
            float(lm.loss_fn(params, cfg, {k: jnp.asarray(v) for k, v in
                                           data.batch(90_000 + i).items()}))
            for i in range(4)
        ]))

    dense_loss = eval_loss(state.params)
    print(f"dense eval loss: {dense_loss:.4f}")

    print(f"== {args.method} pruning to "
          f"{'standard' if args.standard else 'transposable'} "
          f"{args.n}:{args.m} ==")
    calib = jnp.asarray(data.batch(0)["tokens"])
    spec = PatternSpec(args.n, args.m, not args.standard)
    pruned, masks = prune_transformer(
        state.params, cfg, tokens=calib, method=args.method, pattern=spec,
        solver=SolverConfig(iters=150), log=print,
        journal_dir=args.journal_dir,
    )
    pruned_loss = eval_loss(pruned)
    mq = np.array(masks["attn"]["wq"][0])
    print(f"pruned eval loss: {pruned_loss:.4f} (dense {dense_loss:.4f})")
    if not args.standard:
        assert is_transposable_nm(mq, args.n, args.m)
        assert is_transposable_nm(mq.T, args.n, args.m)
        print("masks verified transposable — backward pass is N:M sparse too")


if __name__ == "__main__":
    main()
