"""Tour of the batched mask-solver engine (repro.service).

    PYTHONPATH=src python examples/mask_service.py [--dir runs/mask-demo]

Submits a transformer-like mix of weight tensors to a MaskService backed by
a disk cache + journal, shows the shape-bucketed batching stats, verifies a
couple of masks bit-match the per-tensor solver, then simulates a crash and
demonstrates resume: a second service over the same directory completes the
full workload without re-solving anything it already finished.
"""
import argparse
import shutil
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.api import (BucketPolicy, MaskService, PatternSpec, SolverConfig,
                       is_transposable_nm, solve_mask)

N, M = 2, 4
PATTERN = PatternSpec(N, M)


def make_workload(seed=0):
    rng = np.random.default_rng(seed)
    tensors = {}
    for l in range(3):
        tensors[f"layer{l}/wq"] = rng.normal(size=(128, 128))
        tensors[f"layer{l}/up"] = rng.normal(size=(128, 256))
        tensors[f"layer{l}/odd"] = rng.normal(size=(100, 60))  # padded internally
    tensors["stacked_qkv"] = rng.normal(size=(3, 64, 64))  # ONE submission
    return {k: v.astype(np.float32) for k, v in tensors.items()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=None,
                    help="service directory (default: fresh temp dir)")
    args = ap.parse_args()
    workdir = args.dir or tempfile.mkdtemp(prefix="mask-service-")

    config = SolverConfig(iters=80)
    policy = BucketPolicy(base=64, growth=4, max_bucket=4096)
    tensors = make_workload()

    print(f"== run 1: interrupted mid-model (dir={workdir}) ==")
    svc = MaskService(config, policy=policy, directory=workdir)
    names = list(tensors)
    for name in names[: len(names) // 2]:  # "crash" halfway through
        svc.solve(tensors[name], PATTERN, name=name)
    print(f"  died after {len(names) // 2}/{len(names)} tensors: "
          f"{svc.stats.summary()}")

    print("== run 2: resume + finish ==")
    svc = MaskService(config, policy=policy, directory=workdir)
    handles = {k: svc.submit(k, v, PATTERN) for k, v in tensors.items()}
    svc.flush()
    masks = {k: h.result() for k, h in handles.items()}
    print(f"  {svc.stats.summary()}")
    print(f"  -> {svc.stats.cache_hits} tensors restored from the journaled "
          f"cache, {svc.stats.blocks_solved} blocks solved fresh")

    # Masks are bit-identical to the per-tensor reference path.
    for name in ("layer0/wq", "layer2/odd"):
        ref = solve_mask(jnp.asarray(tensors[name]), PATTERN, config)
        assert (np.array(masks[name]) == np.array(ref)).all(), name
        assert is_transposable_nm(np.array(masks[name]), N, M)
    stacked = np.array(masks["stacked_qkv"])
    assert stacked.shape == tensors["stacked_qkv"].shape
    assert all(is_transposable_nm(stacked[i], N, M) for i in range(stacked.shape[0]))
    print("  masks verified: transposable + bit-identical to the direct solver")

    print("== run 3: fully cached (re-pruning is near-free) ==")
    svc = MaskService(config, policy=policy, directory=workdir)
    for k, v in tensors.items():
        svc.submit(k, v, PATTERN)
    svc.flush()
    print(f"  {svc.stats.summary()}")
    assert svc.stats.blocks_solved == 0

    if args.dir is None:
        shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
