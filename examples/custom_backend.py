"""Registering a custom solver backend and a custom pruning method.

    PYTHONPATH=src python examples/custom_backend.py

The unified API (``repro.api``) exposes two registries:

* ``register_backend`` — per-block transposable mask solvers, selected by
  ``SolverConfig(backend=...)`` and usable everywhere a built-in backend is
  (``solve_mask``, ``MaskService``, ``sparsify_pytree``, ...);
* ``register_method`` — layer-wise pruning frameworks with the unified
  ``(w, gram, pattern, ctx) -> (w_pruned, mask)`` signature, selected by
  ``prune_transformer(method=...)``.

This demo registers a toy backend (row-then-column greedy, the "Bi-NM"
baseline of Zhang et al. 2023) and a toy pruning method (second-moment
scaled magnitude), then runs both through the standard entry points.
"""
import jax.numpy as jnp
import numpy as np

from repro.api import (
    MaskService,
    PatternSpec,
    SolverConfig,
    get_method,
    is_transposable_nm,
    objective,
    register_backend,
    register_method,
    solve_mask,
)
from repro.core.baselines import bi_nm
from repro.pruning.methods import PruneContext


# -- 1. a custom solver backend ---------------------------------------------


@register_backend
class BiNMBackend:
    """Row-wise top-N then column-wise top-N (a fast, weaker baseline)."""

    name = "bi-nm"
    traceable = True  # pure JAX: the service may shard it over devices

    def solve(self, w_abs_blocks, pattern, config):
        return bi_nm(jnp.asarray(w_abs_blocks, jnp.float32), pattern.n)


# -- 2. a custom pruning method ---------------------------------------------


@register_method("scaled-magnitude")
def scaled_magnitude(w, gram, pattern, ctx):
    """|W| scaled by the per-input-feature RMS of the calibration batch."""
    scale = jnp.sqrt(jnp.mean(ctx.x**2, axis=0) + 1e-8)
    scores = jnp.abs(w) * scale[:, None]
    mask = solve_mask(scores, pattern, ctx.solver)
    return jnp.where(mask, w, 0), mask


def main():
    rng = np.random.default_rng(0)
    spec = PatternSpec(4, 8)
    w = jnp.asarray(rng.normal(size=(64, 48)).astype(np.float32))

    print("== custom backend through solve_mask and MaskService ==")
    cfg_binm = SolverConfig(backend="bi-nm")
    cfg_full = SolverConfig(iters=150)
    mask_binm = solve_mask(w, spec, cfg_binm)
    mask_full = solve_mask(w, spec, cfg_full)
    assert is_transposable_nm(np.array(mask_binm), spec.n, spec.m)
    f_b, f_t = float(objective(mask_binm, w)), float(objective(mask_full, w))
    print(f"objective: bi-nm backend {f_b:.1f} vs full TSENOR {f_t:.1f} "
          f"(TSENOR +{100 * (f_t - f_b) / f_b:.2f}%)")

    svc = MaskService(cfg_binm)
    mask_svc = svc.solve(w, spec, name="demo")
    assert (np.array(mask_svc) == np.array(mask_binm)).all()
    print(f"service routed through it too: {svc.stats.summary()}")

    print("== custom pruning method through the registry ==")
    x = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))
    method = get_method("scaled-magnitude")
    wp, mask = method(w, None, spec, PruneContext(x=x, solver=cfg_full))
    assert is_transposable_nm(np.array(mask), spec.n, spec.m)
    kept = float(jnp.mean(mask))
    print(f"scaled-magnitude pruned: kept {kept:.3f} "
          f"(target {spec.density:.3f}); usable as "
          f"prune_transformer(method='scaled-magnitude') on attention+MLP "
          f"families")


if __name__ == "__main__":
    main()
