"""End-to-end driver: pretrain -> prune -> SPARSE fine-tune with transposable
masks, with fault-tolerant checkpointing throughout.

    PYTHONPATH=src python examples/sparse_finetune.py               # ~30M params
    PYTHONPATH=src python examples/sparse_finetune.py --preset tiny # CI-sized
    PYTHONPATH=src python examples/sparse_finetune.py --preset 100m # full driver

This is the paper's motivating workload: after TSENOR pruning, BOTH the
forward matmuls (W·x) and the backward input-gradient matmuls (Wᵀ·g) of the
fine-tune are N:M-sparse-accelerable, because the masks are transposable.
Interrupt it (Ctrl-C) and re-run: it resumes from the latest checkpoint.
"""
import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.api import PatternSpec, SolverConfig
from repro.data import SyntheticLM
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import AdamW, warmup_cosine
from repro.sparsity.masks import apply_mask, mask_sparsity, sparsify_pytree
from repro.train import TrainLoop, TrainLoopConfig, build_train_step, make_train_state

PRESETS = {
    "tiny": ModelConfig("ft-tiny", "dense", num_layers=2, d_model=64,
                        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                        remat="none", dtype="float32"),
    "30m": ModelConfig("ft-30m", "dense", num_layers=6, d_model=384,
                       num_heads=6, num_kv_heads=2, d_ff=1536, vocab_size=8192,
                       remat="none", dtype="float32"),
    "100m": ModelConfig("ft-100m", "dense", num_layers=12, d_model=768,
                        num_heads=12, num_kv_heads=4, d_ff=2048,
                        vocab_size=32768, remat="none", dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--pretrain-steps", type=int, default=120)
    ap.add_argument("--finetune-steps", type=int, default=120)
    ap.add_argument("--n", type=int, default=8)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_sparse_finetune")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print(f"== {cfg.name}: ~{cfg.param_count() / 1e6:.1f}M params ==")
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.batch)

    # Phase 1: dense pretrain (fault-tolerant; resumes automatically).
    opt = AdamW(learning_rate=warmup_cosine(3e-3, 20, args.pretrain_steps))
    ckpt = CheckpointManager(os.path.join(args.ckpt_dir, cfg.name, "dense"),
                             keep_n=2)
    state = make_train_state(cfg, opt, jax.random.PRNGKey(0))
    loop = TrainLoop(build_train_step(cfg, opt, donate=False), data, ckpt,
                     TrainLoopConfig(total_steps=args.pretrain_steps,
                                     ckpt_every=50, log_every=20))
    state, hist = loop.run(state)
    print(f"dense final loss {hist[-1]['loss']:.4f}" if hist else "(resumed done)")

    # Phase 2: TSENOR transposable masks for every projection.
    print(f"== solving transposable {args.n}:{args.m} masks (TSENOR) ==")
    masks = sparsify_pytree(state.params, PatternSpec(args.n, args.m),
                            config=SolverConfig(iters=200, block_batch=1 << 15))
    print(f"mask sparsity {mask_sparsity(masks):.3f}")
    pruned = apply_mask(state.params, masks)

    # Phase 3: sparse fine-tune — both passes N:M-accelerable.
    opt_ft = AdamW(learning_rate=warmup_cosine(1e-3, 10, args.finetune_steps))
    ckpt_ft = CheckpointManager(os.path.join(args.ckpt_dir, cfg.name, "sparse"),
                                keep_n=2)
    st = make_train_state(cfg, opt_ft, jax.random.PRNGKey(1))
    st = st._replace(params=jax.tree.map(jnp.copy, pruned))
    loop_ft = TrainLoop(build_train_step(cfg, opt_ft, masks=masks), data, ckpt_ft,
                        TrainLoopConfig(total_steps=args.finetune_steps,
                                        ckpt_every=50, log_every=20))
    st, hist_ft = loop_ft.run(st)

    def eval_loss(params):
        return float(np.mean([
            float(lm.loss_fn(params, cfg, {k: jnp.asarray(v) for k, v in
                                           data.batch(90_000 + i).items()}))
            for i in range(4)
        ]))

    print(f"dense {eval_loss(state.params):.4f} | "
          f"pruned {eval_loss(pruned):.4f} | "
          f"sparse-finetuned {eval_loss(apply_mask(st.params, masks)):.4f}")


if __name__ == "__main__":
    main()
