"""End-to-end driver: pretrain -> prune -> SPARSE fine-tune with transposable
masks, with fault-tolerant checkpointing throughout.

    PYTHONPATH=src python examples/sparse_finetune.py               # ~30M params
    PYTHONPATH=src python examples/sparse_finetune.py --preset tiny # CI-sized
    PYTHONPATH=src python examples/sparse_finetune.py --preset 100m # full driver
    PYTHONPATH=src python examples/sparse_finetune.py --compressed  # SparseParams
    PYTHONPATH=src python examples/sparse_finetune.py --dst         # decaying N:M

This is the paper's motivating workload: after TSENOR pruning, BOTH the
forward matmuls (W·x) and the backward input-gradient matmuls (Wᵀ·g) of the
fine-tune are N:M-sparse-accelerable, because the masks are transposable.
With ``--compressed`` the fine-tune actually executes that way: the pruned
projections are stored as (values, int8 indices) ``NMCompressed`` buffers,
every matmul streams them through the nm_spmm kernel, and the optimizer
state lives on the compressed shapes.  Note the two runs are not directly
comparable: ``--compressed`` prunes the projection matmuls only
(``projection_prunable`` — the surface the kernel executes), while the
default run also masks the embed/unembed tables.  Over the *same* mask set
the compressed step is bit-identical to masked-dense training — that
property is asserted in ``tests/test_compressed_exec.py``.  Interrupt it
(Ctrl-C) and re-run: it resumes from the latest checkpoint.

``--dst`` (implies ``--compressed``) runs the fine-tune as *dynamic* sparse
training: it starts from a looser transposable pattern and decays N down to
the target on a :func:`repro.dst.schedule.decaying_nm` schedule, re-solving
masks through the MaskService on a background flush while the trainer keeps
stepping, and swapping the live compressed support at each stage boundary
(surviving weights and optimizer moments carry over).  Per-refresh flip
rates are printed at the end.  A DST run's controller state rides the
checkpoints, so interrupting mid-schedule resumes mid-schedule.
"""
import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.api import PatternSpec, SolverConfig
from repro.data import SyntheticLM
from repro.dst import MaskRefreshController, decaying_nm
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import AdamW, warmup_cosine
from repro.sparsity.masks import apply_mask, mask_sparsity, sparsify_pytree
from repro.sparsity.params import (
    compress_params,
    decompress_params,
    projection_prunable,
    sparse_param_bytes,
)
from repro.train import TrainLoop, TrainLoopConfig, build_train_step, make_train_state
from repro.train.step import StepConfig

PRESETS = {
    "tiny": ModelConfig("ft-tiny", "dense", num_layers=2, d_model=64,
                        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                        remat="none", dtype="float32"),
    "30m": ModelConfig("ft-30m", "dense", num_layers=6, d_model=384,
                       num_heads=6, num_kv_heads=2, d_ff=1536, vocab_size=8192,
                       remat="none", dtype="float32"),
    "100m": ModelConfig("ft-100m", "dense", num_layers=12, d_model=768,
                        num_heads=12, num_kv_heads=4, d_ff=2048,
                        vocab_size=32768, remat="none", dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--pretrain-steps", type=int, default=120)
    ap.add_argument("--finetune-steps", type=int, default=120)
    ap.add_argument("--n", type=int, default=8)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_sparse_finetune")
    ap.add_argument("--compressed", action="store_true",
                    help="fine-tune from SparseParams (NMCompressed buffers) "
                         "instead of masked dense weights")
    ap.add_argument("--dst", action="store_true",
                    help="dynamic sparse training: decay N down to --n over "
                         "the fine-tune on an async mask-refresh schedule "
                         "(implies --compressed)")
    args = ap.parse_args()
    if args.dst:
        args.compressed = True

    cfg = PRESETS[args.preset]
    print(f"== {cfg.name}: ~{cfg.param_count() / 1e6:.1f}M params ==")
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.batch)

    # Phase 1: dense pretrain (fault-tolerant; resumes automatically).
    opt = AdamW(learning_rate=warmup_cosine(3e-3, 20, args.pretrain_steps))
    ckpt = CheckpointManager(os.path.join(args.ckpt_dir, cfg.name, "dense"),
                             keep_n=2)
    state = make_train_state(cfg, opt, jax.random.PRNGKey(0))
    loop = TrainLoop(build_train_step(cfg, opt, donate=False), data, ckpt,
                     TrainLoopConfig(total_steps=args.pretrain_steps,
                                     ckpt_every=50, log_every=20))
    state, hist = loop.run(state)
    print(f"dense final loss {hist[-1]['loss']:.4f}" if hist else "(resumed done)")

    # Phase 2: TSENOR transposable masks for every projection.  A DST run
    # prunes to its schedule's *initial* (looser) pattern; the decay down to
    # the target happens live, during the fine-tune.
    solver_cfg = SolverConfig(iters=200, block_batch=1 << 15)
    initial = PatternSpec(args.n, args.m)
    schedule = None
    if args.dst:
        n_start = min(args.m - 1, (args.n + args.m) // 2)
        schedule = decaying_nm(args.m, n_start, args.n,
                               total_steps=args.finetune_steps // 2)
        initial = schedule.initial
        stages = " -> ".join(p.canonical for _, p in schedule.stages)
        print(f"== DST schedule: {stages} over the fine-tune ==")
    print(f"== solving transposable {initial.n}:{initial.m} masks (TSENOR) ==")
    prunable_kw = dict(prunable=projection_prunable) if args.compressed else {}
    masks = sparsify_pytree(state.params, initial, config=solver_cfg,
                            **prunable_kw)
    print(f"mask sparsity {mask_sparsity(masks):.3f}")
    pruned = apply_mask(state.params, masks)

    # Phase 3: sparse fine-tune — both passes N:M-accelerable.  With
    # --compressed the step consumes SparseParams: no masks, no dense W.
    opt_ft = AdamW(learning_rate=warmup_cosine(1e-3, 10, args.finetune_steps))
    subdir = "dst" if args.dst else "compressed" if args.compressed else "sparse"
    ckpt_ft = CheckpointManager(os.path.join(args.ckpt_dir, cfg.name, subdir),
                                keep_n=2)
    if args.compressed:
        sp = compress_params(pruned, masks, initial)
        acc = sparse_param_bytes(sp)
        print(f"== compressed projections: {acc['compressed'] / 1e6:.2f} MB "
              f"({acc['ratio']:.3f}x of {acc['dense'] / 1e6:.2f} MB dense) ==")
        refresh = None
        if args.dst:
            refresh = MaskRefreshController(schedule, solver=solver_cfg,
                                            lookahead=10, mode="async",
                                            log=print)
        # Copy before the donating loop: dense leaves (embed/norms) share
        # buffers with the evaluation params.
        st = make_train_state(cfg, opt_ft, jax.random.PRNGKey(1),
                              params=jax.tree.map(jnp.copy, sp))
        step_ft = build_train_step(cfg, opt_ft,
                                   step_cfg=StepConfig(mask_mode="compressed",
                                                       refresh=refresh))
    else:
        st = make_train_state(cfg, opt_ft, jax.random.PRNGKey(1),
                              params=jax.tree.map(jnp.copy, pruned))
        step_ft = build_train_step(cfg, opt_ft, masks=masks)
    loop_ft = TrainLoop(step_ft, data, ckpt_ft,
                        TrainLoopConfig(total_steps=args.finetune_steps,
                                        ckpt_every=50, log_every=20))
    st, hist_ft = loop_ft.run(st)
    if args.dst:
        ctrl = loop_ft.refresh
        print(f"== DST refreshes: {len(ctrl.events)} "
              f"(stalled {ctrl.stall_seconds() * 1e3:.1f}ms total) ==")
        for e in ctrl.events:
            print(f"  {e.summary()}")

    def eval_loss(params):
        return float(np.mean([
            float(lm.loss_fn(params, cfg, {k: jnp.asarray(v) for k, v in
                                           data.batch(90_000 + i).items()}))
            for i in range(4)
        ]))

    if args.compressed:
        ft_params = st.params  # evaluate straight from the compressed tree
        # Exact only when every projection fits one nm_spmm K-tile (256);
        # larger dims accumulate per tile and differ from dense in ULPs.
        # Same f32-roundoff tolerance as benchmarks/train_step_sparse.py.
        drift = abs(eval_loss(ft_params) - eval_loss(decompress_params(st.params)))
        print(f"compressed vs decompressed-dense eval delta: {drift:.3e}")
        assert drift < 1e-4, drift
    else:
        ft_params = apply_mask(st.params, masks)
    print(f"dense {eval_loss(state.params):.4f} | "
          f"pruned {eval_loss(pruned):.4f} | "
          f"sparse-finetuned {eval_loss(ft_params):.4f}")


if __name__ == "__main__":
    main()
