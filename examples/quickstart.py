"""Quickstart: find a transposable N:M mask for a weight matrix.

    PYTHONPATH=src python examples/quickstart.py [--n 8] [--m 16]

Shows the full TSENOR pipeline (Dykstra -> greedy -> local search), verifies
both orientations are N:M sparse, compares against the baselines the paper
benchmarks, and round-trips the compressed TPU storage format.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (
    PatternSpec,
    SolverConfig,
    is_transposable_nm,
    objective,
    solve_mask,
)
from repro.core.baselines import bi_nm, max_k_random, two_approx
from repro.core.blocks import to_blocks
from repro.kernels.nm_spmm.ops import nm_linear
from repro.sparsity.compressed import compress_nm, compressed_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--size", type=int, default=256)
    args = ap.parse_args()
    n, m = args.n, args.m
    spec = PatternSpec(n, m)

    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(args.size, args.size)).astype(np.float32))

    print(f"== TSENOR transposable {n}:{m} mask for a {args.size}^2 matrix ==")
    mask = solve_mask(w, spec, SolverConfig(iters=300))
    assert is_transposable_nm(np.array(mask), n, m)
    assert is_transposable_nm(np.array(mask).T, n, m)
    print(f"mask sparsity: {1 - float(jnp.mean(mask)):.3f} "
          f"(target {1 - n / m:.3f}); BOTH W and W^T are {n}:{m} sparse")

    blocks = to_blocks(jnp.abs(w), m)
    f_ts = float(objective(mask, w))
    for name, mk in (
        ("2-approximation", two_approx(blocks, n)),
        ("Bi-NM", bi_nm(blocks, n)),
        ("Max1000", max_k_random(jax.random.PRNGKey(0), blocks, n, 256)),
    ):
        from repro.core.blocks import from_blocks
        f_b = float(objective(from_blocks(mk, w.shape), w))
        print(f"objective vs {name:16s}: TSENOR {f_ts:9.1f} vs {f_b:9.1f} "
              f"(+{100 * (f_ts - f_b) / f_b:.2f}%)")

    print("\n== compressed TPU format (values + int8 indices) ==")
    vals, idx = compress_nm(w, mask, n, m)
    acc = compressed_bytes(args.size, args.size, n, m, bytes_w=4)
    print(f"HBM bytes: dense {acc['dense']:,} -> compressed {acc['compressed']:,} "
          f"({acc['ratio']:.2f}x); mem-bound speedup ~{1 / acc['ratio']:.2f}x")
    x = jnp.asarray(rng.normal(size=(4, args.size)).astype(np.float32))
    y = nm_linear(x, vals, idx, m)
    y_ref = x @ (w * mask)
    print(f"nm_linear max err vs dense-masked: "
          f"{float(jnp.max(jnp.abs(y - y_ref))):.2e}")
    print("the SAME buffer serves the backward pass (transposable!)")


if __name__ == "__main__":
    main()
